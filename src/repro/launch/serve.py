"""Serving launcher: continuous batched decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --reduced \
        --requests 8 --max-new 16

A minimal but real serving loop: a request queue feeds fixed-slot batches;
each slot tracks its own cache position; prefill fills a slot's KV cache,
then the shared decode step advances every active slot one token per tick
(static shapes — slots, not ragged batches). Greedy sampling.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_reduced
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_decode_step
from repro.models import lm
from repro.models.lm import _attn_layout
from repro.serve.queue import BufferFull, SlotPool


class Server:
    def __init__(self, cfg, max_len=128, slots=4, dtype=jnp.float32,
                 seed=0):
        self.cfg = cfg
        self.max_len = max_len
        self.slots = slots
        self.params = lm.init_params(cfg, jax.random.PRNGKey(seed), dtype)
        self.cache = lm.init_cache(cfg, slots, max_len, dtype)
        self.pos = np.zeros((slots,), np.int32)
        # decode slots come from the same SlotPool primitive the spike
        # server uses for session lanes (repro.serve.queue); its mask
        # is the `active` vector the batched tick indexes
        self.pool = SlotPool(slots)
        self.tokens = np.zeros((slots,), np.int32)
        self.outputs = [[] for _ in range(slots)]
        self._decode = jax.jit(make_decode_step(cfg))

    @property
    def active(self) -> np.ndarray:
        return self.pool.mask

    def admit(self, prompt):
        """Claim a free decode slot and run the prompt token-by-token
        through the shared decode step (slot-local prefill keeps every
        shape static). Returns the slot id; raises if the pool is
        full — callers wanting back-pressure pass a timeout to
        `pool.acquire` themselves."""
        slot = self.pool.acquire()
        if slot is None:
            # same structured backpressure signal the spike server's
            # ingestion queue raises — the portal maps it to 503
            raise BufferFull(self.slots, self.slots,
                             what="decode slot pool")
        self.outputs[slot] = []
        for t in prompt:
            lg, self.cache = self._decode(
                self.params, self._tok_batch(slot, t),
                self.cache, jnp.int32(int(self.pos[slot])))
            self.pos[slot] += 1
        self.tokens[slot] = int(np.argmax(np.asarray(lg)[slot,
                                          :self.cfg.vocab_size]))
        return slot

    def _tok_batch(self, slot, tok):
        b = np.zeros((self.slots, 1), np.int32)
        b[slot, 0] = tok
        return jnp.asarray(b)

    def tick(self):
        """One decode step for all active slots (continuous batching).
        Slots whose stream hits max_len are released back to the pool,
        ready for the next admit."""
        if not self.active.any():
            return
        pos = int(self.pos[self.active][0])
        batch = jnp.asarray(self.tokens[:, None].astype(np.int32))
        lg, self.cache = self._decode(self.params, batch, self.cache,
                                      jnp.int32(pos))
        nxt = np.argmax(np.asarray(lg)[:, :self.cfg.vocab_size], axis=1)
        for s in range(self.slots):
            if self.active[s]:
                self.outputs[s].append(int(nxt[s]))
                self.tokens[s] = nxt[s]
                self.pos[s] += 1
                if self.pos[s] >= self.max_len - 1:
                    self.pool.release(s)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    args = ap.parse_args(argv)
    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    mesh = make_local_mesh()
    rng = np.random.default_rng(0)
    with mesh_context(mesh):
        srv = Server(cfg, max_len=args.prompt_len + args.max_new + 2,
                     slots=args.requests)
        t0 = time.time()
        for _ in range(args.requests):
            prompt = rng.integers(1, cfg.vocab_size,
                                  args.prompt_len).tolist()
            srv.admit(prompt)
        for _ in range(args.max_new):
            srv.tick()
        dt = time.time() - t0
        total = sum(len(o) for o in srv.outputs)
        print(f"served {args.requests} requests, {total} tokens "
              f"in {dt:.2f}s ({total / dt:.1f} tok/s)")
        for s, out in enumerate(srv.outputs):
            print(f"  req{s}: {out[:10]}...")
    return total


if __name__ == "__main__":
    main()
