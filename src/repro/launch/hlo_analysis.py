"""Loop-aware HLO cost extraction for the roofline.

``compiled.cost_analysis()`` on XLA:CPU visits each while body ONCE, so a
126-layer scanned transformer would report ~1 layer of FLOPs. This module
re-derives the three roofline inputs from ``compiled.as_text()`` (post-SPMD,
per-device shapes), multiplying every while body by its trip count:

  flops            — 2 * prod(result) * prod(contracting dims) per dot
  hbm_bytes        — Σ (operand + result bytes) over HBM-touching ops
                     (fusion/dot/copy/collectives/...); fusion internals are
                     on-chip and not recounted
  collective_bytes — Σ result bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute

Trip counts are recovered from each while condition's comparison constant
(the scan length), which is how XLA lowers lax.scan / lax.map.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * b
    return total


def _shape_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclass
class _Op:
    name: str
    rtype: str
    opcode: str
    rest: str          # operand list + attributes (rest of line)


@dataclass
class _Comp:
    name: str
    ops: List[_Op] = field(default_factory=list)
    params: Dict[str, str] = field(default_factory=dict)


# Opcodes whose operands/results count as HBM traffic. XLA:CPU leaves many
# elementwise ops unfused that XLA:TPU would fuse into neighbors; counting
# only fusion-boundary ops (fusions, dots, data movement, collectives)
# approximates the TPU HBM traffic the roofline models.
_HOT = {
    "fusion", "dot", "copy", "reduce", "scatter", "gather", "concatenate",
    "dynamic-update-slice", "dynamic-slice", "sort", "convolution",
    "reduce-window",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}

# cheap view-like ops we exclude from HBM accounting (no data movement)
_FREE = {"bitcast", "reshape", "tuple", "get-tuple-element", "parameter",
         "constant", "after-all", "iota", "broadcast"}


def parse_module(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and ("->" in stripped or
                                       stripped.startswith("ENTRY")):
            m = _COMP_RE.match(stripped)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(stripped)
        if m:
            name, rtype, opcode, rest = m.groups()
            cur.ops.append(_Op(name, rtype, opcode, rest))


def _trip_count(cond: _Comp) -> int:
    """XLA lowers scan/map to while(i < N); grab N from the condition."""
    best = 1
    consts = {}
    for op in cond.ops:
        if op.opcode == "constant":
            cm = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if cm:
                consts[op.name] = int(cm.group(1))
    for op in cond.ops:
        if op.opcode == "compare":
            for operand in re.findall(r"%?([\w.\-]+)", op.rest):
                if operand in consts and consts[operand] > best:
                    best = consts[operand]
    if best == 1 and consts:
        best = max(list(consts.values()) + [1])
    return max(best, 1)


def _dot_flops(op: _Op, symtab: Dict[str, str]) -> int:
    rdims = _shape_dims(op.rtype)
    out = 1
    for d in rdims:
        out *= d
    # contracting dims from lhs
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = re.findall(r"%?([\w.\-]+)", op.rest.split(")")[0])
    contract = 1
    if cm and operands:
        lhs_type = symtab.get(operands[0], "")
        ldims = _shape_dims(lhs_type)
        idxs = [int(i) for i in cm.group(1).split(",") if i != ""]
        for i in idxs:
            if i < len(ldims):
                contract *= ldims[i]
    return 2 * out * contract


def analyze(text: str) -> Dict[str, float]:
    comps: Dict[str, _Comp] = {}
    cur = None
    entry = None
    for raw in text.splitlines():
        s = _COMMENT_RE.sub("", raw).strip()
        if not s:
            continue
        if s.endswith("{") and "->" in s:
            m = _COMP_RE.match(s)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if m:
            name, rtype, opcode, rest = m.groups()
            cur.ops.append(_Op(name, rtype.strip(), opcode, rest))

    memo: Dict[str, Tuple[float, float, float]] = {}

    def callee_names(rest: str, key: str) -> List[str]:
        m = re.search(key + r"=%?([\w.\-]+)", rest)
        return [m.group(1)] if m else []

    def fusion_read_bytes(cname: str) -> float:
        """Bytes a fusion actually reads: parameters consumed only through
        (dynamic-)slice/gather count as the slice size — a scanned layer
        stack is read one layer at a time, not 126 layers per step."""
        comp = comps.get(cname)
        if comp is None:
            return 0.0
        view_ops = {"dynamic-slice", "slice", "gather", "bitcast", "reshape",
                    "get-tuple-element", "transpose", "copy", "convert"}
        total = 0.0
        for p in comp.ops:
            if p.opcode != "parameter":
                continue
            if p.rtype.lstrip().startswith("("):
                continue
            consumers = [o for o in comp.ops if o is not p
                         and re.search(r"%?" + re.escape(p.name) + r"\b",
                                       o.rest)]
            slicey = [o for o in consumers
                      if o.opcode in ("dynamic-slice", "slice", "gather")]
            if consumers and all(o.opcode in view_ops for o in consumers) \
                    and slicey:
                total += sum(_shape_bytes(o.rtype) for o in slicey)
            else:
                total += _shape_bytes(p.rtype)
        return total

    _CASTY = ("convert_", "copy_", "bitcast_", "transpose_")

    def cost(cname: str, top: bool) -> Tuple[float, float, float, float]:
        """(flops, hbm_bytes, coll_bytes, hbm_tight). top=False inside
        fusion: only flops/collectives counted (memory is on-chip).
        hbm_tight additionally drops copies and pure cast/copy fusions that
        XLA:TPU fuses into neighbors (XLA:CPU leaves them materialized)."""
        key = cname + ("#t" if top else "#f")
        if key in memo:
            return memo[key]
        comp = comps.get(cname)
        if comp is None:
            return (0.0, 0.0, 0.0, 0.0)
        memo[key] = (0.0, 0.0, 0.0, 0.0)     # cycle guard
        symtab = {op.name: op.rtype for op in comp.ops}
        fl = hb = cb = ht = 0.0
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                fl += _dot_flops(op, symtab)
            if any(oc.startswith(c) for c in COLLECTIVES) \
                    and not oc.endswith("-done"):
                cb += _shape_bytes(op.rtype)
            if oc == "while":
                body = callee_names(op.rest, "body")
                cond = callee_names(op.rest, "condition")
                trips = _trip_count(comps[cond[0]]) if cond and \
                    cond[0] in comps else 1
                for b in body:
                    bf, bh, bc, bt = cost(b, top)
                    fl += trips * bf
                    hb += trips * bh
                    cb += trips * bc
                    ht += trips * bt
                continue
            if oc in ("fusion",):
                for c in callee_names(op.rest, "calls"):
                    cf, _, cc, _ = cost(c, False)
                    fl += cf
                    cb += cc
            if oc in ("call", "conditional", "async-start"):
                for c in callee_names(op.rest, "calls") + \
                        callee_names(op.rest, "to_apply"):
                    cf, ch, cc, ct = cost(c, top)
                    fl += cf
                    hb += ch
                    cb += cc
                    ht += ct
            if top and oc in _HOT:
                # HBM traffic: result + reads. Tuple-typed operands (loop
                # state plumbing) are skipped; fusion reads are derived from
                # the fusion body so sliced layer-stacks count one slice.
                b = _shape_bytes(op.rtype)
                if oc == "fusion":
                    for c in callee_names(op.rest, "calls"):
                        b += fusion_read_bytes(c)
                else:
                    ops_str = op.rest.split(")")[0]
                    for operand in set(re.findall(r"%?([\w.\-]+)", ops_str)):
                        t = symtab.get(operand)
                        if t and not t.lstrip().startswith("("):
                            b += _shape_bytes(t)
                hb += b
                casty = oc == "copy" or (
                    oc == "fusion" and op.name.startswith(_CASTY))
                if not casty:
                    ht += b
        memo[key] = (fl, hb, cb, ht)
        return memo[key]

    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0,
                "hbm_bytes_tight": 0.0}
    fl, hb, cb, ht = cost(entry, True)
    return {"flops": fl, "hbm_bytes": hb, "collective_bytes": cb,
            "hbm_bytes_tight": ht}


def collective_breakdown(text: str) -> Dict[str, float]:
    """Per-collective-type bytes (loop-unaware quick view, for reports)."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        s = line.strip()
        m = _OP_RE.match(s)
        if not m:
            continue
        _, rtype, opcode, _ = m.groups()
        for c in COLLECTIVES:
            if opcode.startswith(c) and not opcode.endswith("-done"):
                out[c] = out.get(c, 0.0) + _shape_bytes(rtype)
    return out
