"""`Telemetry` — the bundle every tier threads through.

One object carries the tracer, metrics registry, and JSON logger so
call sites take a single `telemetry=` argument instead of three. The
`on` property toggles tracer + registry together at runtime, which is
how the overhead benches A/B the same warmed server (no recompiles, no
process restarts) between obs-on and obs-off.

`Telemetry()` is cheap to build, so tiers that receive `telemetry=None`
construct an enabled default — telemetry is always AVAILABLE; only its
cost profile changes with the toggle.
"""
from __future__ import annotations

from typing import Optional

from .logs import JsonLogger
from .metrics import MetricsRegistry
from .trace import Tracer

__all__ = ["Telemetry"]


class Telemetry:
    """Tracer + metrics registry + structured logger, one handle.

        tel = Telemetry(log_json="-")          # logs to stdout
        with tel.tracer.span("dispatch", trace_id=tid) as sp: ...
        tel.metrics.counter("repro_requests_total", "...").inc()
        tel.log.request(trace_id=tid, status=200, ...)
        tel.on = False                         # obs-off A/B arm
    """

    def __init__(self, *, on: bool = True,
                 trace_capacity: int = 4096,
                 log_json: Optional[str] = None):
        self.tracer = Tracer(capacity=trace_capacity, on=on)
        self.metrics = MetricsRegistry(on=on)
        self.log = JsonLogger(log_json)

    @property
    def on(self) -> bool:
        return self.tracer.on

    @on.setter
    def on(self, value: bool) -> None:
        value = bool(value)
        self.tracer.on = value
        self.metrics.on = value

    @classmethod
    def disabled(cls) -> "Telemetry":
        """A telemetry bundle with tracing + metrics off and no log
        sink — the cheapest configuration, for overhead baselines."""
        return cls(on=False)

    def stats(self) -> dict:
        return {"on": self.on, "tracer": self.tracer.stats(),
                "log_written": self.log.written}

    def __repr__(self) -> str:
        return f"Telemetry(on={self.on})"
