"""Observability subsystem: request tracing, unified metrics,
structured logging.

Stdlib-only by design — bridge worker processes (which must stay
numpy/jax-free) import this package, and so does the serving tier.
See `trace` (spans + Chrome trace export), `metrics` (Prometheus
registry), `logs` (JSON request log), `telemetry` (the bundle tiers
thread through).
"""
from .logs import JsonLogger, request_record
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      log_buckets, merge_snapshots, parse_prometheus,
                      render_snapshot, snapshot_by_worker,
                      snapshot_with_label)
from .telemetry import Telemetry
from .trace import (Span, Tracer, chrome_trace, new_trace_id,
                    validate_chrome_trace)

__all__ = [
    "Span", "Tracer", "new_trace_id", "chrome_trace",
    "validate_chrome_trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "log_buckets",
    "merge_snapshots", "render_snapshot", "snapshot_by_worker",
    "snapshot_with_label", "parse_prometheus",
    "JsonLogger", "request_record",
    "Telemetry",
]
