"""Request tracing — lightweight spans with trace-id propagation.

A *span* is one named, timed stage of one request's journey through
the stack (http parse -> bridge hop -> queue wait -> `run_lanes`
dispatch). Spans carry a shared `trace_id`, so every stage of one
request reassembles into a single trace no matter which process or
thread recorded it, and a `parent_id` giving the nesting.

Design constraints (this module is on the serving hot path):

  * stdlib-only — bridge WORKER processes import it (no numpy/jax);
  * off-is-free — a disabled `Tracer` hands out one shared no-op span
    and touches no lock, so telemetry can ship enabled-by-default and
    still be toggled off for A/B overhead runs;
  * bounded — finished spans land in a ring buffer (`deque(maxlen=)`),
    so an always-on server never grows without bound; exporters drain
    snapshots, they never block recording;
  * cross-process timestamps — `time.monotonic_ns()` is CLOCK_MONOTONIC,
    which on Linux is one system-wide clock: spans recorded in a
    front-end worker and in the dispatcher order correctly in one
    Perfetto view.

Export is Chrome trace-event JSON (the `{"traceEvents": [...]}` array
of `"ph": "X"` complete events), loadable in Perfetto / chrome://tracing;
`validate_chrome_trace` is the structural check CI's trace-export smoke
runs against generated files.
"""
from __future__ import annotations

import itertools
import os
import random
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Span", "Tracer", "new_trace_id", "chrome_trace",
           "validate_chrome_trace"]

_ids = itertools.count(1)

# pid cached at import (one getpid syscall per Span otherwise — this
# module is on the serving hot path); refreshed after fork so a forked
# child never stamps its parent's pid
_pid = os.getpid()
_pid_hex = f"{_pid:x}"


# urandom-seeded PRNG for trace ids: os.urandom is a getrandom(2)
# syscall per call, and ids only need uniqueness, not secrecy
_rng = random.Random(os.urandom(16))


def _refresh_pid() -> None:
    global _pid, _pid_hex, _rng
    _pid = os.getpid()
    _pid_hex = f"{_pid:x}"
    _rng = random.Random(os.urandom(16))


if hasattr(os, "register_at_fork"):
    os.register_at_fork(after_in_child=_refresh_pid)


def new_trace_id() -> str:
    """16-hex-char random trace id (propagated via `X-Trace-Id`)."""
    return f"{_rng.getrandbits(64):016x}"


def _new_span_id() -> str:
    return f"{_pid_hex}.{next(_ids):x}"


class Span:
    """One finished (or in-flight) stage. `start`/`end` are
    monotonic nanoseconds; `attrs` is a small flat dict of JSON-able
    values (model, bucket, batch size, ...)."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id",
                 "start", "end", "attrs", "pid", "tid",
                 "_tracer")

    def __init__(self, name: str, trace_id: str, span_id: str,
                 parent_id: Optional[str], start: int,
                 attrs: Optional[dict], tracer: Optional["Tracer"]):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.start = start
        self.end: Optional[int] = None
        # ownership transfer, not a copy — the caller's kwargs dict is
        # always fresh, and this runs per request on the serving path
        self.attrs: Dict = attrs if attrs is not None else {}
        self.pid = _pid
        self.tid = threading.get_ident()
        self._tracer = tracer

    # ------------------------------------------------------- lifecycle
    def finish(self, end: Optional[int] = None, **attrs) -> "Span":
        """Close the span (idempotent) and commit it to the tracer's
        ring buffer."""
        if self.end is None:
            self.end = time.monotonic_ns() if end is None else int(end)
            if attrs:
                self.attrs.update(attrs)
            if self._tracer is not None:
                self._tracer._commit(self)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.finish()

    # ------------------------------------------------------------ wire
    @property
    def duration_ms(self) -> float:
        end = self.end if self.end is not None else time.monotonic_ns()
        return (end - self.start) / 1e6

    def ctx(self) -> dict:
        """Propagation context for a child stage in another
        process/thread: `{"trace_id", "parent"}`."""
        return {"trace_id": self.trace_id, "parent": self.span_id}

    def to_dict(self) -> dict:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "start": self.start, "end": self.end,
                "pid": self.pid, "tid": self.tid, "attrs": self.attrs}

    @classmethod
    def from_dict(cls, d: dict) -> "Span":
        s = cls(d["name"], d["trace_id"], d["span_id"],
                d.get("parent_id"), int(d["start"]),
                dict(d.get("attrs") or {}), None)
        s.end = None if d.get("end") is None else int(d["end"])
        s.pid = int(d.get("pid", 0))
        s.tid = int(d.get("tid", 0))
        return s

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"{self.duration_ms:.3f} ms)")


class _NullSpan:
    """The shared do-nothing span a disabled tracer hands out — every
    operation is a constant-time no-op, so `tracer.span(...)` costs one
    attribute check when telemetry is off."""

    __slots__ = ()
    trace_id = ""
    span_id = ""
    parent_id = None
    name = ""
    attrs: Dict = {}
    start = 0
    end = 0
    duration_ms = 0.0

    def finish(self, end=None, **attrs) -> "_NullSpan":
        return self

    def ctx(self) -> None:
        return None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring buffer of finished spans.

        tracer = Tracer(capacity=4096)
        with tracer.span("dispatch", trace_id=tid, model="demo") as sp:
            ...                                  # timed region
        events = chrome_trace(tracer.spans())    # Perfetto-loadable

    `on` is the runtime toggle: when False, `span()` returns the shared
    no-op span (no allocation, no lock). `record()` ingests spans
    serialized in ANOTHER process (the bridge piggybacks worker spans
    onto its frames so the dispatcher ring holds the whole trace).
    """

    def __init__(self, capacity: int = 4096, on: bool = True):
        self.capacity = int(capacity)
        self.on = bool(on)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.dropped = 0          # spans evicted by the ring bound

    # ---------------------------------------------------------- record
    def span(self, name: str, *, trace_id: Optional[str] = None,
             parent: Optional[str] = None, ctx: Optional[dict] = None,
             start: Optional[int] = None, **attrs):
        """Open a span. Pass `ctx=` (a `Span.ctx()` dict, e.g. decoded
        off a bridge frame) OR explicit `trace_id`/`parent`. `start`
        backdates the span (monotonic ns) for stages measured before
        their ids were known (http parse)."""
        if not self.on:
            return NULL_SPAN
        if ctx:
            trace_id = ctx.get("trace_id") or trace_id
            parent = ctx.get("parent") or parent
        return Span(name, trace_id or new_trace_id(), _new_span_id(),
                    parent,
                    time.monotonic_ns() if start is None else int(start),
                    attrs, self)

    def span_record(self, name: str, *, trace_id: Optional[str] = None,
                    parent: Optional[str] = None,
                    ctx: Optional[dict] = None,
                    start: int, end: int, **attrs) -> Optional[dict]:
        """Build one already-finished span as a PLAIN DICT (same wire
        shape as `Span.to_dict`) without committing it — the
        dispatcher's per-request fast path. Batch the dicts and commit
        them with ONE `record_batch` call per micro-batch; they are
        normalized to `Span`s lazily, at snapshot time."""
        if not self.on:
            return None
        if ctx:
            trace_id = ctx.get("trace_id") or trace_id
            parent = ctx.get("parent") or parent
        return {"name": name, "trace_id": trace_id or new_trace_id(),
                "span_id": _new_span_id(), "parent_id": parent,
                "start": start, "end": end, "pid": _pid,
                "tid": threading.get_ident(), "attrs": attrs}

    def record_batch(self, spans: List[dict]) -> None:
        """Commit a batch of finished span dicts under one lock."""
        if not self.on or not spans:
            return
        with self._lock:
            overflow = len(self._ring) + len(spans) - self._ring.maxlen
            if overflow > 0:
                self.dropped += overflow
            self._ring.extend(spans)

    def _commit(self, span: Span) -> None:
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(span)

    def record(self, spans) -> None:
        """Ingest externally-recorded spans (dicts or `Span`s) into the
        ring — the dispatcher side of worker-span forwarding."""
        if not self.on:
            return
        with self._lock:
            for s in spans:
                if isinstance(s, dict):
                    s = Span.from_dict(s)
                if len(self._ring) == self._ring.maxlen:
                    self.dropped += 1
                self._ring.append(s)

    # ---------------------------------------------------------- export
    def spans(self, trace_id: Optional[str] = None) -> List[Span]:
        """Snapshot of the ring (optionally one trace), oldest first.
        `record_batch` dicts are normalized to `Span`s here — export
        pays the object cost, not the serving hot path."""
        with self._lock:
            out = [s if isinstance(s, Span) else Span.from_dict(s)
                   for s in self._ring]
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped = 0

    def stats(self) -> dict:
        with self._lock:
            return {"spans": len(self._ring),
                    "capacity": self.capacity,
                    "dropped": self.dropped, "on": self.on}


# ------------------------------------------------------- chrome export
def chrome_trace(spans) -> dict:
    """Spans -> Chrome trace-event JSON (Perfetto / chrome://tracing).
    Each span becomes one complete ("ph": "X") event; `ts`/`dur` are
    microseconds on the shared monotonic clock, so worker and
    dispatcher tracks align in one view. The trace id and span ids ride
    in `args` (Perfetto shows them in the event detail pane)."""
    events = []
    for s in spans:
        if isinstance(s, dict):
            s = Span.from_dict(s)
        if s.end is None:
            continue
        args = {"trace_id": s.trace_id, "span_id": s.span_id}
        if s.parent_id:
            args["parent_id"] = s.parent_id
        args.update(s.attrs)
        events.append({"name": s.name, "cat": "obs", "ph": "X",
                       "ts": s.start / 1e3,
                       "dur": max(s.end - s.start, 0) / 1e3,
                       "pid": s.pid, "tid": s.tid, "args": args})
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_chrome_trace(obj) -> List[str]:
    """Structural check of a Chrome trace-event JSON object. Returns a
    list of problems (empty = valid) — the CI trace-export smoke fails
    on any. Checks the keys/types the format requires for "X" events
    plus this exporter's own contract (trace_id in args, dur >= 0)."""
    problems = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with 'traceEvents'"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            problems.append(f"{where}: not an object")
            continue
        for key, types in (("name", str), ("ph", str),
                           ("ts", (int, float)), ("pid", int),
                           ("tid", int)):
            if not isinstance(e.get(key), types):
                problems.append(f"{where}: missing/bad {key!r}")
        if e.get("ph") == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where}: 'X' event needs dur >= 0")
        args = e.get("args", {})
        if not isinstance(args, dict) or not args.get("trace_id"):
            problems.append(f"{where}: args.trace_id missing")
    return problems
