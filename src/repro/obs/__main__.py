"""CLI for the observability subsystem.

    python -m repro.obs demo --out trace.json        # synthetic trace
    python -m repro.obs validate trace.json          # structural check
    python -m repro.obs fetch http://host:port --out trace.json
                                                     # pull /trace from
                                                     # a running portal

`validate` exits non-zero on any structural problem — it is the check
CI's trace-export smoke runs against generated files. `demo` emits a
small but realistic span tree (request -> bridge -> queue wait ->
dispatch) without needing a server, so the exporter/validator pair can
be smoked anywhere. `fetch` grabs a live portal's trace export (and
optionally its /metrics) using only stdlib HTTP.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.request

from .trace import Tracer, chrome_trace, new_trace_id, \
    validate_chrome_trace

__all__ = ["main"]


def _demo_spans(tracer: Tracer, n_requests: int = 3) -> None:
    """Synthesize the canonical 4-stage request shape."""
    for i in range(n_requests):
        tid = new_trace_id()
        root = tracer.span("http_request", trace_id=tid,
                           method="POST", path="/v1/demo/run")
        bridge = tracer.span("gateway_call", ctx=root.ctx(), op="run")
        qw = tracer.span("queue_wait", ctx=bridge.ctx(), model="demo")
        time.sleep(0.001)
        qw.finish()
        disp = tracer.span("dispatch", ctx=bridge.ctx(),
                           model="demo", batch_size=i + 1,
                           bucket=1 << i)
        time.sleep(0.002)
        disp.finish()
        bridge.finish()
        root.finish(status=200)


def _cmd_demo(args) -> int:
    tracer = Tracer()
    _demo_spans(tracer, args.requests)
    doc = chrome_trace(tracer.spans())
    problems = validate_chrome_trace(doc)
    if problems:
        for p in problems:
            print(f"invalid: {p}", file=sys.stderr)
        return 1
    _write(args.out, doc)
    print(f"wrote {len(doc['traceEvents'])} events -> {args.out}")
    return 0


def _cmd_validate(args) -> int:
    with open(args.file, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    problems = validate_chrome_trace(doc)
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    if problems:
        for p in problems:
            print(f"invalid: {p}", file=sys.stderr)
        return 1
    traces = {e["args"]["trace_id"] for e in events}
    print(f"ok: {len(events)} events, {len(traces)} trace(s)")
    return 0


def _cmd_fetch(args) -> int:
    base = args.url.rstrip("/")
    req = urllib.request.Request(base + "/trace")
    if args.token:
        req.add_header("Authorization", f"Bearer {args.token}")
    with urllib.request.urlopen(req, timeout=args.timeout) as resp:
        doc = json.loads(resp.read().decode("utf-8"))
    problems = validate_chrome_trace(doc)
    if problems:
        for p in problems:
            print(f"invalid: {p}", file=sys.stderr)
        return 1
    _write(args.out, doc)
    print(f"fetched {len(doc['traceEvents'])} events -> {args.out}")
    return 0


def _write(path: str, doc: dict) -> None:
    if path == "-":
        json.dump(doc, sys.stdout)
        sys.stdout.write("\n")
        return
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(doc, fh)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    d = sub.add_parser("demo", help="write a synthetic Chrome trace")
    d.add_argument("--out", default="trace.json")
    d.add_argument("--requests", type=int, default=3)
    d.set_defaults(fn=_cmd_demo)

    v = sub.add_parser("validate",
                       help="structurally validate a Chrome trace file")
    v.add_argument("file")
    v.set_defaults(fn=_cmd_validate)

    f = sub.add_parser("fetch",
                       help="download /trace from a running portal")
    f.add_argument("url")
    f.add_argument("--out", default="trace.json")
    f.add_argument("--token", default=None)
    f.add_argument("--timeout", type=float, default=10.0)
    f.set_defaults(fn=_cmd_fetch)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
