"""Structured request logging — one JSON line per request.

`JsonLogger` writes newline-delimited JSON records to a path or stdout
(`--log-json PATH|-` on the `repro.portal` / `repro.serve` CLIs). Each
record is a flat dict; `request_record` builds the canonical per-request
shape the tests pin:

    {"ts": <unix seconds>, "event": "request", "trace_id": ...,
     "token": <label>, "model": ..., "op": "run", "status": 200,
     "code": null | "E_*", "bucket": 4, "batch_size": 3,
     "queue_wait_ms": ..., "dispatch_ms": ..., "latency_ms": ...}

Lines are serialized outside the lock and written with a single
`write()` call in append mode, so concurrent writers (multi-worker
portals pointing at one file) interleave whole lines, never bytes.
Stdlib-only; a logger built with `path=None` is a no-op.
"""
from __future__ import annotations

import io
import json
import sys
import threading
import time
from typing import Optional

__all__ = ["JsonLogger", "request_record"]


def request_record(*, trace_id: str = "", token: str = "",
                   model: str = "", op: str = "run",
                   status: int = 200, code: Optional[str] = None,
                   **extra) -> dict:
    """Canonical per-request log record. Stage latencies / batch info
    arrive via `extra` (queue_wait_ms, dispatch_ms, latency_ms, bucket,
    batch_size, ...) so callers only pass what they measured."""
    rec = {"ts": round(time.time(), 6), "event": "request",
           "trace_id": trace_id, "token": token, "model": model,
           "op": op, "status": int(status), "code": code}
    rec.update(extra)
    return rec


class JsonLogger:
    """Newline-delimited JSON sink.

    `target` is a filesystem path, `"-"` for stdout, or None for a
    disabled logger (every `write()` is a cheap no-op — the off-by-
    default arm). Files are opened lazily in append mode and lines are
    flushed per record, so `tail -f` and crash-time forensics both
    work.
    """

    def __init__(self, target: Optional[str] = None):
        self.target = target
        self._lock = threading.Lock()
        self._fh: Optional[io.TextIOBase] = None
        self.written = 0

    @property
    def enabled(self) -> bool:
        return self.target is not None

    def _handle(self):
        if self.target == "-":
            return sys.stdout
        if self._fh is None or self._fh.closed:
            self._fh = open(self.target, "a", encoding="utf-8")
        return self._fh

    def write(self, record: dict) -> None:
        if self.target is None:
            return
        line = json.dumps(record, separators=(",", ":"),
                          default=str) + "\n"
        with self._lock:
            fh = self._handle()
            fh.write(line)
            fh.flush()
            self.written += 1

    def request(self, **fields) -> None:
        """`write(request_record(**fields))` — the one-liner call sites
        use."""
        self.write(request_record(**fields))

    def close(self) -> None:
        with self._lock:
            if self._fh is not None and not self._fh.closed:
                self._fh.close()

    def __repr__(self) -> str:
        state = self.target if self.enabled else "disabled"
        return f"JsonLogger({state}, written={self.written})"
