"""Unified metrics registry — counters, gauges, log-bucketed latency
histograms, Prometheus text exposition.

One `MetricsRegistry` per process collects every stat the stack used
to scatter across ad-hoc dicts (`SpikeServer.stats()`, `DoubleBuffer`
swap counts, per-token auth counters, `AccessCounter` level tallies,
retrace compile counts) and renders them as ONE Prometheus text
document at `GET /metrics`.

Pieces:

  * `Counter` / `Gauge` / `Histogram` — labeled metric families.
    Histograms are log-bucketed (`log_buckets`): exponentially spaced
    boundaries cover 0.25 ms .. 8 s in 16 buckets, the right shape for
    latencies spanning queue-wait microseconds to compile seconds.
  * callbacks — `registry.register_callback(fn)` runs `fn(registry)`
    at collect time, for values that live elsewhere (queue depth,
    SlotPool occupancy, jit cache entries): scrape-time gauges instead
    of write-through instrumentation on hot paths.
  * snapshots — `collect()` returns a JSON-able snapshot;
    `render_merged(snapshots)` sums counters/histograms across worker
    processes (the bridge forwards worker snapshots to the dispatcher,
    so `/metrics` answers with AGGREGATED totals, satellite-fixing the
    documented per-worker split) while per-worker breakdowns stay
    visible under a `worker` label.
  * `parse_prometheus` — a small exposition parser used by tests to
    assert the rendered text round-trips.

Stdlib-only (bridge workers import it); all mutation under one lock
per registry; disabled registries (`on=False`) short-circuit every
observation to a no-op for A/B overhead runs.
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "log_buckets", "render_snapshot", "merge_snapshots",
           "snapshot_by_worker", "snapshot_with_label",
           "parse_prometheus"]


def log_buckets(lo: float = 0.25, hi: float = 8000.0,
                per_decade: Optional[int] = None,
                base: float = 2.0) -> List[float]:
    """Exponentially spaced histogram boundaries from `lo` up to at
    least `hi` (default: powers of two, 0.25 ms .. ~8 s)."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    if per_decade is not None:
        base = 10.0 ** (1.0 / per_decade)
    out, v = [], float(lo)
    while v < hi * (1 + 1e-12):
        out.append(v)
        v *= base
    if out[-1] < hi:
        out.append(v)
    return out


def _label_key(labelnames: Sequence[str], labels: dict) -> Tuple:
    # hot path: build the key directly and let a KeyError signal the
    # mismatch — no per-call set allocations
    if len(labels) != len(labelnames):
        raise ValueError(f"expected labels {list(labelnames)}, "
                         f"got {sorted(labels)}")
    try:
        return tuple(str(labels[n]) for n in labelnames)
    except KeyError:
        raise ValueError(f"expected labels {list(labelnames)}, "
                         f"got {sorted(labels)}") from None


def _fmt_labels(labelnames, key, extra=()) -> str:
    parts = [f'{n}="{_escape(v)}"'
             for n, v in list(zip(labelnames, key)) + list(extra)]
    return "{" + ",".join(parts) + "}" if parts else ""


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _fmt_val(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


class _Metric:
    """Common labeled-family machinery. Child values are keyed by the
    tuple of label values; unlabeled families use the empty tuple."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (),
                 registry: Optional["MetricsRegistry"] = None):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._reg = registry
        self._lock = registry._lock if registry is not None \
            else threading.Lock()

    def _on(self) -> bool:
        return self._reg is None or self._reg.on


class Counter(_Metric):
    """Monotonically increasing count. `inc(n, **labels)`."""

    kind = "counter"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._values: Dict[Tuple, float] = {}

    def inc(self, n: float = 1.0, **labels) -> None:
        if not self._on():
            return
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(
                _label_key(self.labelnames, labels), 0.0)

    def _samples(self):
        with self._lock:
            items = sorted(self._values.items())
        return [(self.name, self.labelnames, k, v) for k, v in items]


class Gauge(_Metric):
    """Point-in-time value. `set(v, **labels)` / `inc` / `dec`."""

    kind = "gauge"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._values: Dict[Tuple, float] = {}

    def set(self, v: float, **labels) -> None:
        if not self._on():
            return
        with self._lock:
            self._values[_label_key(self.labelnames, labels)] = float(v)

    def inc(self, n: float = 1.0, **labels) -> None:
        if not self._on():
            return
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n

    def dec(self, n: float = 1.0, **labels) -> None:
        self.inc(-n, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(
                _label_key(self.labelnames, labels), 0.0)

    def _samples(self):
        with self._lock:
            items = sorted(self._values.items())
        return [(self.name, self.labelnames, k, v) for k, v in items]


class Histogram(_Metric):
    """Log-bucketed distribution. `observe(v, **labels)` adds one
    sample; exposition renders cumulative `_bucket{le=...}` series plus
    `_sum`/`_count` (standard Prometheus histogram semantics, so rate()
    + histogram_quantile() work). `quantile(q)` gives a bucket-resolved
    estimate for in-process assertions (upper bound of the bucket the
    q-th sample falls in)."""

    kind = "histogram"

    def __init__(self, name, help, labelnames=(), registry=None,
                 buckets: Optional[Sequence[float]] = None):
        super().__init__(name, help, labelnames, registry)
        bs = list(buckets) if buckets is not None else log_buckets()
        if sorted(bs) != bs or len(set(bs)) != len(bs):
            raise ValueError("histogram buckets must be strictly "
                             "increasing")
        self.buckets = [float(b) for b in bs]
        self._counts: Dict[Tuple, List[int]] = {}
        self._sum: Dict[Tuple, float] = {}
        self._n: Dict[Tuple, int] = {}

    def _bucket_index(self, v: float) -> int:
        """Index of the first boundary >= v (len(buckets) = +Inf)."""
        return bisect_left(self.buckets, v)

    def observe(self, v: float, **labels) -> None:
        if not self._on():
            return
        v = float(v)
        key = _label_key(self.labelnames, labels)
        i = self._bucket_index(v)
        with self._lock:
            if key not in self._counts:
                self._counts[key] = [0] * (len(self.buckets) + 1)
                self._sum[key] = 0.0
                self._n[key] = 0
            self._counts[key][i] += 1
            self._sum[key] += v
            self._n[key] += 1

    def observe_many(self, values: Sequence[float], **labels) -> None:
        """Add a batch of samples under ONE key build + lock acquire —
        the serving hot path records a whole micro-batch per call."""
        if not self._on() or not values:
            return
        key = _label_key(self.labelnames, labels)
        idx = [self._bucket_index(float(v)) for v in values]
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = \
                    [0] * (len(self.buckets) + 1)
                self._sum[key] = 0.0
                self._n[key] = 0
            for i in idx:
                counts[i] += 1
            self._sum[key] += float(sum(values))
            self._n[key] += len(values)

    def count(self, **labels) -> int:
        with self._lock:
            return self._n.get(_label_key(self.labelnames, labels), 0)

    def sum(self, **labels) -> float:
        with self._lock:
            return self._sum.get(_label_key(self.labelnames, labels),
                                 0.0)

    def quantile(self, q: float, **labels) -> float:
        """Upper bound of the bucket holding the q-th sample (0<=q<=1);
        inf if it landed in the overflow bucket, 0.0 with no samples."""
        key = _label_key(self.labelnames, labels)
        with self._lock:
            counts = list(self._counts.get(key, ()))
            n = self._n.get(key, 0)
        if not n:
            return 0.0
        rank = max(1, math.ceil(q * n))
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank:
                return self.buckets[i] if i < len(self.buckets) \
                    else math.inf
        return math.inf

    def _samples(self):
        out = []
        with self._lock:
            items = sorted(self._counts.items())
            sums = dict(self._sum)
            ns = dict(self._n)
        for key, counts in items:
            cum = 0
            for b, c in zip(self.buckets + [math.inf], counts):
                cum += c
                out.append((self.name + "_bucket", self.labelnames,
                            key, cum, (("le", _fmt_val(b)),)))
            out.append((self.name + "_sum", self.labelnames, key,
                        sums[key]))
            out.append((self.name + "_count", self.labelnames, key,
                        ns[key]))
        return out


class MetricsRegistry:
    """Family registry + exposition renderer. `on=False` short-circuits
    every observation (the obs-off arm of the overhead bench); the
    toggle is live (`registry.on = False`) so A/B runs reuse warmed
    servers."""

    def __init__(self, on: bool = True):
        self.on = bool(on)
        self._lock = threading.RLock()
        self._families: Dict[str, _Metric] = {}
        self._callbacks: List[Callable] = []

    # ------------------------------------------------------- factories
    def _family(self, cls, name, help, labelnames, **kw):
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if type(fam) is not cls \
                        or fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind} with labels {fam.labelnames}")
                return fam
            fam = cls(name, help, labelnames, registry=self, **kw)
            self._families[name] = fam
            return fam

    def counter(self, name, help, labelnames=()) -> Counter:
        return self._family(Counter, name, help, labelnames)

    def gauge(self, name, help, labelnames=()) -> Gauge:
        return self._family(Gauge, name, help, labelnames)

    def histogram(self, name, help, labelnames=(),
                  buckets=None) -> Histogram:
        return self._family(Histogram, name, help, labelnames,
                            buckets=buckets)

    def register_callback(self, fn: Callable) -> None:
        """`fn(registry)` runs at every `collect()` — set scrape-time
        gauges (queue depth, compile-cache entries) there instead of
        instrumenting hot paths."""
        with self._lock:
            self._callbacks.append(fn)

    # ------------------------------------------------------ exposition
    def collect(self) -> dict:
        """JSON-able snapshot: {name: {"kind", "help", "labelnames",
        "samples": [[name, labelvalues, value, extra-label-pairs]]}}.
        The unit the bridge ships worker->dispatcher."""
        if self.on:
            with self._lock:
                callbacks = list(self._callbacks)
            for fn in callbacks:
                fn(self)
        out = {}
        with self._lock:
            fams = list(self._families.items())
        for name, fam in fams:
            samples = []
            for s in fam._samples():
                sname, _, key, value = s[0], s[1], s[2], s[3]
                extra = list(s[4]) if len(s) > 4 else []
                samples.append([sname, list(key), value,
                                [list(p) for p in extra]])
            out[name] = {"kind": fam.kind, "help": fam.help,
                         "labelnames": list(fam.labelnames),
                         "samples": samples}
        return out

    def render(self, extra_snapshots: Sequence[dict] = ()) -> str:
        """Prometheus text exposition of this registry merged with any
        forwarded snapshots (see `merge_snapshots`)."""
        snaps = [self.collect()] + list(extra_snapshots)
        return render_snapshot(merge_snapshots(snaps))


# -------------------------------------------------- snapshot machinery
def merge_snapshots(snapshots: Sequence[dict]) -> dict:
    """Fold several `collect()` snapshots into one: counter and
    histogram samples with identical (name, labels) SUM; gauges keep
    the last value seen. This is how `/metrics` answers with
    bridge-aggregated totals while per-worker series (which carry a
    distinct `worker` label) pass through untouched."""
    out: dict = {}
    for snap in snapshots:
        for name, fam in snap.items():
            dst = out.setdefault(name, {"kind": fam["kind"],
                                        "help": fam["help"],
                                        "labelnames":
                                            list(fam["labelnames"]),
                                        "_acc": {}})
            acc = dst["_acc"]
            for sname, key, value, extra in fam["samples"]:
                k = (sname, tuple(key),
                     tuple(tuple(p) for p in extra))
                if fam["kind"] == "gauge":
                    acc[k] = value
                else:
                    acc[k] = acc.get(k, 0) + value
    for fam in out.values():
        fam["samples"] = [[sname, list(key), v,
                           [list(p) for p in extra]]
                          for (sname, key, extra), v
                          in sorted(fam.pop("_acc").items())]
    return out


def _sample_order(sample):
    """Render order within a family: bucket rows by numeric `le`
    (not lexically — "+Inf" must come last), then _sum, then _count."""
    sname, key, _value, extra = sample
    le = 0.0
    for k, v in extra:
        if k == "le":
            le = math.inf if v == "+Inf" else float(v)
    rank = 2 if sname.endswith("_count") else \
        1 if sname.endswith("_sum") else 0
    return (tuple(key), rank, le, sname)


def render_snapshot(snapshot: dict) -> str:
    """One merged snapshot -> Prometheus text exposition 0.0.4."""
    lines = []
    for name in sorted(snapshot):
        fam = snapshot[name]
        lines.append(f"# HELP {name} {fam['help']}")
        lines.append(f"# TYPE {name} {fam['kind']}")
        for sname, key, value, extra in sorted(fam["samples"],
                                               key=_sample_order):
            labelnames = list(fam["labelnames"])
            # snapshots may carry wider keys (a merged-in worker label)
            if len(key) == len(labelnames) + 1:
                labelnames = labelnames + ["worker"]
            labels = _fmt_labels(labelnames, key,
                                 tuple(tuple(p) for p in extra))
            lines.append(f"{sname}{labels} {_fmt_val(value)}")
    return "\n".join(lines) + "\n"


def snapshot_by_worker(snapshot: dict, worker) -> dict:
    """Re-home a worker's snapshot under `<family>_by_worker` families
    with a trailing `worker` label — the per-worker breakdown kept
    ALONGSIDE the aggregated base series (separate family names, so
    downstream `sum()` queries over the base series never
    double-count)."""
    out = {}
    for name, fam in snapshot.items():
        new = name + "_by_worker"
        out[new] = {
            "kind": fam["kind"],
            "help": fam["help"] + " (per-worker breakdown)",
            "labelnames": list(fam["labelnames"]) + ["worker"],
            "samples": [[new + sname[len(name):],
                         list(key) + [str(worker)], v,
                         [list(p) for p in extra]]
                        for sname, key, v, extra in fam["samples"]],
        }
    return out


def snapshot_with_label(snapshot: dict, label: str,
                        value: str) -> dict:
    """Append `label=value` to every sample of a snapshot — the
    per-worker breakdown (`worker="<pid>"`) kept alongside the
    aggregated series."""
    out = {}
    for name, fam in snapshot.items():
        out[name] = {
            "kind": fam["kind"], "help": fam["help"],
            "labelnames": list(fam["labelnames"]),
            "samples": [[sname, key, v,
                         [list(p) for p in extra]
                         + [[label, str(value)]]]
                        for sname, key, v, extra in fam["samples"]],
        }
    return out


# ------------------------------------------------------------- parsing
def parse_prometheus(text: str) -> Dict[str, Dict[frozenset, float]]:
    """Tiny exposition parser (the subset `render` emits): returns
    {series name: {frozenset(label pairs): value}}. Used by tests to
    assert the endpoint's output is parseable and numerically equal to
    the in-process stats it unifies."""
    out: Dict[str, Dict[frozenset, float]] = {}
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        if "{" in ln:
            name, rest = ln.split("{", 1)
            labelpart, valpart = rest.rsplit("}", 1)
            labels = []
            for item in _split_labels(labelpart):
                k, v = item.split("=", 1)
                v = v.strip()[1:-1]
                v = v.replace(r'\"', '"').replace(r"\n", "\n") \
                    .replace(r"\\", "\\")
                labels.append((k.strip(), v))
            value = valpart.strip()
        else:
            name, value = ln.split(None, 1)
            labels = []
        out.setdefault(name.strip(), {})[frozenset(labels)] = \
            float(value)
    return out


def _split_labels(s: str) -> List[str]:
    """Split 'a="x",b="y,z"' on commas outside quotes."""
    out, buf, in_q, esc = [], "", False, False
    for ch in s:
        if esc:
            buf += ch
            esc = False
            continue
        if ch == "\\":
            buf += ch
            esc = True
            continue
        if ch == '"':
            in_q = not in_q
        if ch == "," and not in_q:
            out.append(buf)
            buf = ""
        else:
            buf += ch
    if buf.strip():
        out.append(buf)
    return out
