"""AdamW (decoupled weight decay) + global-norm clipping + cosine schedule.

Written from scratch (no optax in this environment). Moment dtype is
configurable per arch (bf16 for llama3-405b / deepseek-v2 to fit HBM —
see EXPERIMENTS.md §Dry-run memory table); math is performed in fp32 and
cast back on store.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(oc: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = oc.lr * step / max(oc.warmup_steps, 1)
    frac = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * oc.lr * (1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def adamw_init(params, oc: AdamWConfig):
    dt = jnp.dtype(oc.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                     grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, state, oc: AdamWConfig):
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(oc.moment_dtype)

    def upd(p, g, m, v):
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay \
            * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mf.astype(mdt), vf.astype(mdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
