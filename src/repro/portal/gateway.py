"""The portal's dispatcher-side half: `LocalGateway` + `Portal`.

`LocalGateway` adapts a `SpikeServer` to the async gateway surface the
transport layers consume (`repro.portal.http`, `.ws`, `.bridge`):
JSON-shaped payloads in, JSON-shaped results out, and every exception
the serving stack can raise mapped onto ONE structured `PortalError`
vocabulary —

    AnalysisError   -> 400, the analyzer's own E_* code, a message
                       that is exactly `report.render()`, and the
                       structured findings
    KeyError        -> 404 E_NO_MODEL / E_NO_SESSION
    BufferFull      -> 503 E_BACKPRESSURE + Retry-After (full
                       DoubleBuffer sheds instead of queueing)
    BufferClosed    -> 503 E_SHUTDOWN + Retry-After
    DeadlineError   -> 504 E_DEADLINE + Retry-After (queue-expired
                       submit timeout)
    DispatchRestart -> 503 E_DISPATCH_RESTART + Retry-After (the
                       supervisor restarted a crashed dispatcher;
                       session state rolled back, safe to retry)
    ValueError      -> 400 E_BAD_REQUEST

`Portal` is the lifecycle wrapper: `workers=0` serves in-process (one
asyncio thread next to the dispatcher), `workers=N` reserves the TCP
port, starts the unix-socket `BridgeServer`, and spawns N jax-free
front-end worker processes that share the port via SO_REUSEPORT.
"""
from __future__ import annotations

import asyncio
import hashlib
import json
import os
import shutil
import subprocess
import sys
import tempfile
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.analysis import AnalysisError
from repro.obs import (chrome_trace, merge_snapshots, render_snapshot,
                       snapshot_by_worker)
from repro.portal.auth import Authenticator, TokenQuota
from repro.portal.bridge import BridgeServer, _reuseport_socket
from repro.portal.errors import PortalError
from repro.portal.http import PortalApp
from repro.serve import (BufferClosed, BufferFull, DeadlineError,
                         DispatchRestart, SpikeServer)

__all__ = ["LocalGateway", "Portal", "map_exception", "result_digest"]


def result_digest(spikes, membrane) -> str:
    """Canonical digest of one served window — sha256 over the bool
    spike raster and the int32 final membranes. The same bytes hash on
    both sides of the wire, so bit-exactness checks (tests, the bench
    gate) compare 64 hex chars instead of shipping arrays around."""
    s = np.ascontiguousarray(np.asarray(spikes), dtype=np.uint8)
    v = np.ascontiguousarray(np.asarray(membrane), dtype="<i4")
    h = hashlib.sha256()
    h.update(np.asarray(s.shape, "<i8").tobytes())
    h.update(s.tobytes())
    h.update(v.tobytes())
    return h.hexdigest()


def map_exception(e: BaseException) -> PortalError:
    """Serving-stack exception -> wire-visible `PortalError`."""
    if isinstance(e, PortalError):
        return e
    if isinstance(e, AnalysisError):
        errs = e.report.errors
        return PortalError(400, errs[0].code if errs else "E_ANALYSIS",
                           str(e), findings=e.report.to_dict())
    if isinstance(e, DeadlineError):
        # a queue-expired request means the dispatcher is saturated
        # right now, not broken: hint a retry after roughly the
        # client's own patience, capped so the hint stays actionable
        return PortalError(504, "E_DEADLINE", str(e),
                           retry_after=max(0.05,
                                           min(e.timeout_s, 5.0)))
    if isinstance(e, BufferFull):
        return PortalError(503, "E_BACKPRESSURE", str(e),
                           retry_after=e.retry_after_s or 0.05)
    if isinstance(e, BufferClosed):
        # during a rolling restart another backend (or this one,
        # re-spawned) answers within about a second
        return PortalError(503, "E_SHUTDOWN",
                           "the server is shutting down",
                           retry_after=1.0)
    if isinstance(e, DispatchRestart):
        return PortalError(503, "E_DISPATCH_RESTART", str(e),
                           retry_after=e.retry_after_s)
    if isinstance(e, KeyError):
        msg = e.args[0] if e.args else str(e)
        code = "E_NO_SESSION" if "session" in str(msg) else "E_NO_MODEL"
        return PortalError(404, code, str(msg))
    if isinstance(e, RuntimeError) and "session lanes" in str(e):
        return PortalError(503, "E_NO_LANES", str(e), retry_after=0.1)
    if isinstance(e, asyncio.TimeoutError):
        return PortalError(504, "E_TIMEOUT",
                           "the dispatcher did not answer in time",
                           retry_after=1.0)
    if isinstance(e, (ValueError, TypeError)):
        return PortalError(400, "E_BAD_REQUEST", str(e))
    return PortalError(500, "E_INTERNAL", f"{type(e).__name__}: {e}")


class LocalGateway:
    """In-process gateway over one `SpikeServer`. Async methods match
    `bridge.GATEWAY_OPS` one for one; the bridge server exposes this
    exact object to remote workers."""

    def __init__(self, server: SpikeServer, *,
                 default_timeout: float = 120.0):
        self.server = server
        self.default_timeout = float(default_timeout)
        # extra (pid, metrics-snapshot) sources merged into /metrics —
        # Portal points this at BridgeServer.worker_snapshots in
        # multi-worker mode so any worker's scrape reports aggregated
        # totals
        self.extra_snapshots = lambda: []

    # ------------------------------------------------------------ run
    def _schedule(self, payload: dict):
        counts = payload.get("counts")
        events = payload.get("events")
        if (counts is None) == (events is None):
            raise PortalError(
                400, "E_BAD_REQUEST",
                "send exactly one of 'counts' (a T x n_axons count "
                "matrix) or 'events' (a length-T list of axon-id "
                "lists)")
        if counts is not None:
            try:
                arr = np.asarray(counts, dtype=np.int64)
            except (ValueError, TypeError):
                raise PortalError(400, "E_BAD_REQUEST",
                                  "'counts' must be a rectangular "
                                  "array of integers")
            if arr.ndim != 2:
                raise PortalError(400, "E_BAD_REQUEST",
                                  f"'counts' must be 2-D (T, n_axons),"
                                  f" got shape {arr.shape}")
            return arr.astype(np.int32)
        if not isinstance(events, list) \
                or not all(isinstance(s, list) for s in events):
            raise PortalError(400, "E_BAD_REQUEST",
                              "'events' must be a list of per-step "
                              "axon-id lists")
        return events

    async def run(self, model: str, payload: dict,
                  trace: Optional[dict] = None) -> dict:
        schedule = self._schedule(payload)
        session = payload.get("session")
        seed = int(payload.get("seed", 0))
        timeout = float(payload.get("timeout",
                                    self.default_timeout))
        span = self.server.tel.tracer.span("gateway_call", ctx=trace,
                                           op="run", model=model)
        try:
            try:
                # submit before the first await: frame order == queue
                # order
                fut = self.server.submit(
                    model, schedule,
                    session=None if session is None else int(session),
                    seed=seed, timeout=timeout, trace=span.ctx())
            except Exception as e:     # noqa: BLE001 — wire boundary
                raise map_exception(e)
            try:
                res = await asyncio.wait_for(asyncio.wrap_future(fut),
                                             timeout + 30.0)
            except asyncio.CancelledError:
                if fut.cancelled():    # dispatcher shut down under us
                    raise map_exception(BufferClosed())
                raise
            except Exception as e:     # noqa: BLE001 — wire boundary
                raise map_exception(e)
        except PortalError as e:
            span.finish(error=e.code)
            raise
        span.finish()
        spikes = np.asarray(res.spikes, dtype=np.uint8)
        membrane = np.asarray(res.membrane)
        return {
            "model": res.model, "session": res.session,
            "steps": int(spikes.shape[0]),
            "spikes": spikes.tolist(),
            "membrane": membrane.tolist(),
            "digest": result_digest(res.spikes, res.membrane),
            "latency_ms": round(float(res.latency_ms), 3),
            "batch_size": int(res.batch_size),
            "bucket": int(res.bucket),
            "queue_wait_ms": round(float(res.queue_wait_ms), 3),
            "dispatch_ms": round(float(res.dispatch_ms), 3),
            "trace_id": res.trace_id,
        }

    async def reconfigure(self, model: str, payload: dict,
                          trace: Optional[dict] = None) -> dict:
        for k in ("pre", "post", "weight"):
            if k not in payload:
                raise PortalError(400, "E_BAD_REQUEST",
                                  f"reconfigure needs 'pre', 'post' "
                                  f"and 'weight' lists (missing {k!r})")
        try:
            fut = self.server.reconfigure(model, payload["pre"],
                                          payload["post"],
                                          payload["weight"])
            uploads = await asyncio.wait_for(
                asyncio.wrap_future(fut), self.default_timeout)
        except asyncio.CancelledError:
            raise map_exception(BufferClosed())
        except Exception as e:         # noqa: BLE001 — wire boundary
            raise map_exception(e)
        return {"model": model, "uploads": int(uploads)}

    # ------------------------------------------------------- sessions
    async def open_session(self, model: str,
                           trace: Optional[dict] = None) -> dict:
        try:
            sid = self.server.open_session(model)
            window = self.server.models[model].window
        except Exception as e:         # noqa: BLE001 — wire boundary
            raise map_exception(e)
        return {"session": int(sid), "model": model,
                "window": int(window)}

    async def close_session(self, model: str, session: int,
                            trace: Optional[dict] = None) -> dict:
        try:
            self.server.close_session(model, int(session))
        except Exception as e:         # noqa: BLE001 — wire boundary
            raise map_exception(e)
        return {"model": model, "closed": int(session)}

    async def reset_session(self, model: str, session: int,
                            trace: Optional[dict] = None) -> dict:
        try:
            self.server.reset_session(model, int(session))
        except Exception as e:         # noqa: BLE001 — wire boundary
            raise map_exception(e)
        return {"model": model, "reset": int(session)}

    async def session_info(self, model: str, session: int,
                           trace: Optional[dict] = None) -> dict:
        try:
            m = self.server._model(model)
            s = m.sessions.get(int(session))
            V = self.server.session_membrane(model, int(session))
        except Exception as e:         # noqa: BLE001 — wire boundary
            raise map_exception(e)
        return {"model": model, "session": int(session),
                "lane": int(s.lane), "requests": int(s.requests),
                "steps": int(s.steps),
                "membrane": np.asarray(V).tolist()}

    # ------------------------------------------------------ telemetry
    async def stats(self, trace: Optional[dict] = None) -> dict:
        out = self.server.stats()
        for m in out["models"].values():
            m["batch_shapes"] = [list(s) for s in m["batch_shapes"]]
        return out

    async def metrics(self, fmt: str = "prometheus",
                      trace: Optional[dict] = None) -> dict:
        """Render the unified metric registry. The aggregate merges the
        dispatcher's own registry with every forwarded worker snapshot
        (counters and histograms SUM), and the per-worker breakdown is
        kept alongside under `<family>_by_worker{worker="<pid>"}`."""
        if fmt == "json":
            return {"server": await self.stats()}
        workers = list(self.extra_snapshots())
        own = self.server.tel.metrics.collect()
        agg = merge_snapshots(
            [own] + [snap for _, snap in workers]
            + [snapshot_by_worker(snap, pid)
               for pid, snap in workers])
        return {"content_type":
                "text/plain; version=0.0.4; charset=utf-8",
                "text": render_snapshot(agg)}

    async def trace_export(self, trace_id: Optional[str] = None,
                           trace: Optional[dict] = None) -> dict:
        """Chrome trace-event JSON of the dispatcher ring (which, in
        multi-worker mode, also holds every forwarded worker span)."""
        return chrome_trace(
            self.server.tel.tracer.spans(trace_id or None))

    async def healthz(self, trace: Optional[dict] = None) -> dict:
        h = self.server.health()
        return {"ok": bool(h["ok"]), "status": h["status"],
                "reason": h["reason"], "restarts": h["restarts"],
                "pid": os.getpid(),
                "dispatcher": h["dispatcher"],
                "queue": h["queue"], "lanes": h["lanes"],
                "models": {
                    name: {"window": m.window,
                           "n_axons": int(m.dep.compiled.n_axons),
                           "n_neurons": int(m.dep.compiled.n_neurons),
                           "open_sessions": m.sessions.n_open}
                    for name, m in self.server.models.items()}}


class Portal:
    """Network front end over one `SpikeServer`.

        srv = SpikeServer(...); srv.add_model("demo", compiled, ...)
        with srv, Portal(srv, port=0, workers=4) as portal:
            print(portal.url)          # http://127.0.0.1:<port>

    `workers=0` (default) serves from an asyncio thread in this
    process; `workers=N` spawns N jax-free front-end processes
    bridged over a unix socket (see `repro.portal.bridge`)."""

    def __init__(self, server: SpikeServer, host: str = "127.0.0.1",
                 port: int = 0, *,
                 tokens: Optional[Dict[str, TokenQuota]] = None,
                 workers: int = 0, default_timeout: float = 120.0,
                 respawn_workers: bool = True):
        self.server = server
        self.host, self.port = host, int(port)
        self.workers = int(workers)
        self.auth = Authenticator(tokens)
        self.gateway = LocalGateway(server,
                                    default_timeout=default_timeout)
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._http_server = None
        self._bridge: Optional[BridgeServer] = None
        self._procs: List[subprocess.Popen] = []
        self._reserve = None
        self._tmpdir: Optional[str] = None
        # worker churn tolerance: a reaper thread polls the front-end
        # processes and respawns any that die (SO_REUSEPORT keeps the
        # shared port reserved, so a respawn rebinds instantly);
        # worker_restarts counts them
        self.respawn_workers = bool(respawn_workers)
        self.worker_restarts = 0
        self._reap_stop = threading.Event()
        self._reaper: Optional[threading.Thread] = None
        self._worker_cmd: Optional[List[str]] = None
        self._worker_env: Optional[Dict[str, str]] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # ------------------------------------------------------ lifecycle
    def start(self) -> "Portal":
        if self._loop is not None:
            raise RuntimeError("portal already started")
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._loop.run_forever,
                                        name="portal-loop", daemon=True)
        self._thread.start()
        try:
            if self.workers <= 0:
                self._call(self._start_inproc())
            else:
                self._start_bridge_mode()
        except BaseException:
            self.stop()
            raise
        return self

    def stop(self) -> None:
        # stop the reaper FIRST so terminated workers are not respawned
        self._reap_stop.set()
        if self._reaper is not None:
            self._reaper.join(timeout=10)
            self._reaper = None
        for p in self._procs:
            p.terminate()
        for p in self._procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=10)
        self._procs = []
        if self._loop is not None:
            if self._http_server is not None:
                self._call(self._stop_server(self._http_server))
                self._http_server = None
            if self._bridge is not None:
                self._call(self._bridge.stop())
                self._bridge = None
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout=10)
            self._loop.close()
            self._loop = self._thread = None
        if self._reserve is not None:
            self._reserve.close()
            self._reserve = None
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
            self._tmpdir = None

    def __enter__(self) -> "Portal":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------- internal
    def _call(self, coro, timeout: float = 60.0):
        return asyncio.run_coroutine_threadsafe(
            coro, self._loop).result(timeout=timeout)

    @staticmethod
    async def _stop_server(server) -> None:
        server.close()
        await server.wait_closed()

    async def _start_inproc(self) -> None:
        # in-process mode shares the server's telemetry bundle: portal
        # spans land in the same ring as serve spans, so one request is
        # one trace with no forwarding step
        app = PortalApp(self.gateway, self.auth,
                        telemetry=self.server.tel)
        self._http_server = await asyncio.start_server(
            app.handle_conn, self.host, self.port)
        self.port = self._http_server.sockets[0].getsockname()[1]

    def _start_bridge_mode(self) -> None:
        # reserve the port: bound (not listening) with SO_REUSEPORT,
        # so every worker can bind the same number and the kernel
        # balances accepts across THEIR listening sockets only
        self._reserve = _reuseport_socket(self.host, self.port)
        self.port = self._reserve.getsockname()[1]
        self._tmpdir = tempfile.mkdtemp(prefix="repro-portal-")
        uds = os.path.join(self._tmpdir, "bridge.sock")
        self._bridge = BridgeServer(self.gateway, uds,
                                    telemetry=self.server.tel)
        # any worker's /metrics now merges every worker's forwarded
        # snapshot — aggregated totals, not worker-local counters
        self.gateway.extra_snapshots = self._bridge.worker_snapshots
        self._call(self._bridge.start())

        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"]
            if env.get("PYTHONPATH") else "")
        spec = self.auth.spec()
        cmd = [sys.executable, "-m", "repro.portal", "--worker",
               "--host", self.host, "--port", str(self.port),
               "--uds", uds]
        if spec is not None:
            cmd += ["--auth-spec", json.dumps(spec)]
        # workers inherit the structured-log sink (append-mode single-
        # write lines, so N processes sharing one file stay line-atomic)
        if self.server.tel.log.target is not None:
            cmd += ["--log-json", self.server.tel.log.target]
        self._procs = [subprocess.Popen(cmd, env=env)
                       for _ in range(self.workers)]
        self._wait_ready()
        if self.respawn_workers:
            self._worker_cmd, self._worker_env = cmd, env
            self._reap_stop.clear()
            self._reaper = threading.Thread(target=self._reap_loop,
                                            name="portal-reaper",
                                            daemon=True)
            self._reaper.start()

    def _reap_loop(self) -> None:
        """Poll the worker processes; respawn any that died. The other
        SO_REUSEPORT listeners keep serving while the replacement
        starts, so a worker crash costs in-flight requests on its
        connections only — new connections land on survivors."""
        while not self._reap_stop.wait(0.25):
            for i, p in enumerate(self._procs):
                if self._reap_stop.is_set():
                    return
                if p.poll() is not None:
                    self.worker_restarts += 1
                    self._procs[i] = subprocess.Popen(
                        self._worker_cmd, env=self._worker_env)

    def _wait_ready(self, timeout: float = 60.0) -> None:
        """Poll /healthz until every worker has answered at least once
        (healthz carries the answering worker's pid)."""
        import http.client

        deadline = time.monotonic() + timeout
        seen = set()
        last_err = None
        while time.monotonic() < deadline:
            if any(p.poll() is not None for p in self._procs):
                raise RuntimeError(
                    "portal worker exited during startup: "
                    + ", ".join(str(p.poll()) for p in self._procs))
            try:
                conn = http.client.HTTPConnection(self.host, self.port,
                                                  timeout=5)
                conn.request("GET", "/healthz")
                resp = conn.getresponse()
                body = json.loads(resp.read().decode("utf-8"))
                conn.close()
                if resp.status == 200:
                    seen.add(body.get("worker_pid"))
                    if len(seen) >= len(self._procs):
                        return
            except (OSError, ValueError) as e:
                last_err = e
            time.sleep(0.05)
        raise RuntimeError(
            f"portal workers not ready after {timeout}s "
            f"({len(seen)}/{len(self._procs)} answered; last error: "
            f"{last_err})")
