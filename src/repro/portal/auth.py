"""Bearer-token authentication and per-token quotas.

The portal's admission control, enforced in the front-end worker
BEFORE anything touches the dispatcher:

  * authentication — `Authorization: Bearer <token>` against a static
    token table (401 `E_AUTH` otherwise; a portal constructed with
    `tokens=None` is open, the local-demo mode);
  * request rate — a token bucket per token (`rate` req/s, `burst`
    capacity): an empty bucket is a 429 `E_QUOTA_RATE` whose
    Retry-After says when the next token accrues;
  * concurrency — at most `max_inflight` requests of one token
    simultaneously in flight across run/reconfigure/stream windows
    (429 `E_QUOTA_INFLIGHT`): one client cannot occupy every lane of
    the micro-batch by pipelining.

Per-token counters (admitted / rejected / in flight) surface as
`repro_token_*` series in the Prometheus `GET /metrics` exposition
(and under `clients` in the legacy `GET /metrics?format=json` view).
Stdlib-only, so bridge workers import it without numpy/jax.

Multi-worker scope: with `--workers N` each SO_REUSEPORT worker
process builds its OWN Authenticator from `spec()`, so quota
ENFORCEMENT is per worker — a client whose connections the kernel
spreads across workers can reach up to N x the configured rate/burst/
max_inflight. REPORTING, however, is global: every worker forwards its
metrics snapshot over the bridge, so `repro_token_admitted_total` /
`repro_token_rejected_total` on `/metrics` are bridge-aggregated
totals from any worker you ask, with the per-worker split preserved
under `repro_token_*_by_worker{worker="<pid>"}`. (Only the legacy
`?format=json` `clients` block remains worker-local.) Size quotas for
the worker count (e.g. rate / N for a hard global rate), or run
`--workers 0` when exact global enforcement matters; the ingestion
backpressure (503 E_BACKPRESSURE) is always global because the
DoubleBuffer lives in the single dispatcher process.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional

from repro.portal.errors import PortalError

__all__ = ["TokenQuota", "TokenState", "Authenticator"]


@dataclass
class TokenQuota:
    """Quota attached to one bearer token. `name` is the label used in
    metrics (never the secret); defaults to a truncated token prefix."""
    rate: float = 50.0          # sustained requests/second
    burst: int = 16             # bucket capacity (instantaneous burst)
    max_inflight: int = 8       # concurrent in-flight requests
    name: Optional[str] = None


class TokenState:
    """Runtime state of one token: its bucket level, in-flight count,
    and counters. All mutation happens under the authenticator lock."""

    def __init__(self, token: str, quota: TokenQuota):
        self.token = token
        self.quota = quota
        self.name = quota.name or (token[:4] + "…")
        self.level = float(quota.burst)     # tokens currently in bucket
        self.last = time.monotonic()
        self.inflight = 0
        self.admitted = 0
        self.rejected_rate = 0
        self.rejected_inflight = 0

    def _refill(self, now: float) -> None:
        self.level = min(float(self.quota.burst),
                         self.level + (now - self.last)
                         * self.quota.rate)
        self.last = now

    def metrics(self) -> dict:
        return {"admitted": self.admitted, "inflight": self.inflight,
                "rejected_rate": self.rejected_rate,
                "rejected_inflight": self.rejected_inflight,
                "rate": self.quota.rate, "burst": self.quota.burst,
                "max_inflight": self.quota.max_inflight}


class _Admission:
    """Context manager pairing one admitted request with its in-flight
    release."""

    def __init__(self, auth: "Authenticator",
                 state: Optional[TokenState]):
        self._auth, self._state = auth, state

    def __enter__(self) -> "_Admission":
        return self

    def __exit__(self, *exc) -> None:
        if self._state is not None:
            with self._auth._lock:
                self._state.inflight -= 1


class Authenticator:
    """Token table + quota enforcement. `tokens=None` disables auth
    entirely (open portal); `{}` locks everyone out."""

    def __init__(self, tokens: Optional[Dict[str, TokenQuota]] = None):
        self._lock = threading.Lock()
        self._states: Optional[Dict[str, TokenState]] = None
        if tokens is not None:
            self._states = {t: TokenState(t, q)
                            for t, q in tokens.items()}

    @property
    def enabled(self) -> bool:
        return self._states is not None

    # ------------------------------------------------------ wire format
    def spec(self) -> Optional[dict]:
        """JSON-serializable token table, for handing to spawned bridge
        workers (each worker enforces quotas for its own connections)."""
        if self._states is None:
            return None
        return {t: {"rate": s.quota.rate, "burst": s.quota.burst,
                    "max_inflight": s.quota.max_inflight,
                    "name": s.name}
                for t, s in self._states.items()}

    @classmethod
    def from_spec(cls, spec: Optional[dict]) -> "Authenticator":
        if spec is None:
            return cls(None)
        return cls({t: TokenQuota(**q) for t, q in spec.items()})

    # ------------------------------------------------------- admission
    def authenticate(self, headers: Dict[str, str]) \
            -> Optional[TokenState]:
        """Resolve the request's token (401 on missing/unknown).
        Returns None when auth is disabled."""
        if self._states is None:
            return None
        raw = headers.get("authorization", "")
        scheme, _, token = raw.partition(" ")
        if scheme.lower() != "bearer" or not token.strip():
            raise PortalError(
                401, "E_AUTH",
                "missing bearer token: send 'Authorization: Bearer "
                "<token>'")
        state = self._states.get(token.strip())
        if state is None:
            raise PortalError(401, "E_AUTH", "unknown bearer token")
        return state

    def admit(self, state: Optional[TokenState]) -> _Admission:
        """Charge one request against the token's quotas (429 with
        Retry-After when over), returning the context manager that
        releases the in-flight slot."""
        if state is None:
            return _Admission(self, None)
        now = time.monotonic()
        with self._lock:
            state._refill(now)
            if state.level < 1.0:
                state.rejected_rate += 1
                wait = (1.0 - state.level) / max(state.quota.rate, 1e-9)
                raise PortalError(
                    429, "E_QUOTA_RATE",
                    f"token {state.name} is over its "
                    f"{state.quota.rate:g} req/s rate "
                    f"(burst {state.quota.burst})",
                    retry_after=wait)
            if state.inflight >= state.quota.max_inflight:
                state.rejected_inflight += 1
                raise PortalError(
                    429, "E_QUOTA_INFLIGHT",
                    f"token {state.name} already has {state.inflight} "
                    f"requests in flight (max "
                    f"{state.quota.max_inflight})",
                    retry_after=0.05)
            state.level -= 1.0
            state.inflight += 1
            state.admitted += 1
        return _Admission(self, state)

    def metrics(self) -> dict:
        """Per-token counters keyed by the metric label (never the
        secret)."""
        if self._states is None:
            return {}
        with self._lock:
            return {s.name: s.metrics() for s in self._states.values()}
