"""Web-portal front end over the serving tier — the paper's "made
easily available over a web portal" delivery layer.

    from repro.portal import Portal, TokenQuota
    from repro.serve import SpikeServer

    srv = SpikeServer(max_batch=8, max_pending=64)
    srv.add_model("demo", compiled, window=8, n_sessions=8)
    with srv, Portal(srv, port=8787, workers=4,
                     tokens={"s3cret": TokenQuota(rate=50)}) as portal:
        ...                      # curl http://127.0.0.1:8787/healthz

Layering (each module one concern):

    errors.py    PortalError — status + E_* code + Retry-After + findings
    auth.py      bearer tokens, token-bucket rate + in-flight quotas
    http.py      HTTP/1.1 on asyncio streams (run/reconfigure/sessions/
                 healthz/metrics)
    ws.py        RFC 6455 websocket streaming sessions (lane-pinned)
    bridge.py    N front-end worker processes over a unix socket, one
                 resident dispatcher (SO_REUSEPORT fan-in)
    gateway.py   LocalGateway over SpikeServer + the Portal lifecycle

Everything except `gateway` is stdlib-only: bridge WORKER processes
import no numpy/jax, which is why this `__init__` resolves the heavy
exports lazily — `python -m repro.portal --worker` must stay light.
`python -m repro.portal` serves a demo model over localhost.
"""
from repro.portal.auth import Authenticator, TokenQuota
from repro.portal.errors import PortalError

__all__ = ["PortalError", "Authenticator", "TokenQuota",
           "Portal", "LocalGateway", "map_exception", "result_digest",
           "WSClient"]

_LAZY = {"Portal": "repro.portal.gateway",
         "LocalGateway": "repro.portal.gateway",
         "map_exception": "repro.portal.gateway",
         "result_digest": "repro.portal.gateway",
         "WSClient": "repro.portal.ws"}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.portal' has no attribute "
                             f"{name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
