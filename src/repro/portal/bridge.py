"""Multi-worker bridge: N front-end processes, ONE resident mesh.

The dispatcher process owns the jax runtime — the resident
`Deployment`s, the compiled lane executables, the micro-batching
thread. Accepting sockets, parsing HTTP, checking tokens, and JSON
(de)serialization are pure-Python work that the GIL serializes against
nothing useful, so the portal splits them out: `--workers N` spawns N
front-end processes that each run the full `PortalApp` (http + ws +
auth) against a `BridgeClient` gateway, forwarding every admitted
request over a unix-domain socket to the `BridgeServer` beside the
dispatcher. All workers listen on the SAME TCP port via SO_REUSEPORT
(the kernel load-balances accepts), so the front end scales with
cores while the model stays resident exactly once.

The wire format is deliberately dumb: 4-byte big-endian length +
UTF-8 JSON, requests tagged with a connection-local `id` so one UDS
connection multiplexes every in-flight request of its worker.
Responses are `{"id": n, "result": ...}` or `{"id": n, "error":
<PortalError.to_body()>}` — errors cross the process boundary with
status/code/Retry-After/findings intact.

Telemetry rides the same frames: an optional `trace` field carries the
request's span-propagation context (`Span.ctx()`) dispatcher-ward, so
one trace id follows a request across the process boundary; `spans`
piggybacks the worker's finished spans (drained from its ring) and
`m` its metrics snapshot, which the `BridgeServer` ingests into the
dispatcher-side telemetry — that is how `/metrics` answers with
AGGREGATED multi-worker totals and `/trace` shows whole cross-process
traces.

Worker processes are spawned as `python -m repro.portal --worker ...`
and import ONLY stdlib modules (this file, http.py, ws.py, auth.py,
errors.py) — never numpy or jax — so they start in tens of
milliseconds and add no accelerator state to fork.
"""
from __future__ import annotations

import asyncio
import itertools
import json
import os
import random
import struct
import time
from typing import Dict, List, Optional, Tuple

from repro import faults
from repro.obs import Telemetry
from repro.portal.auth import Authenticator
from repro.portal.errors import PortalError

__all__ = ["BridgeServer", "BridgeClient", "run_worker",
           "GATEWAY_OPS"]

# every gateway method a worker may invoke remotely — op names double
# as the method names on both gateway implementations
GATEWAY_OPS = ("run", "reconfigure", "open_session", "close_session",
               "reset_session", "session_info", "stats", "healthz",
               "metrics", "trace_export")

# worker metric snapshots are piggybacked at most this often on
# ordinary frames (scrape ops always carry a fresh one)
_M_FLUSH_S = 0.5

_MAX_MSG = 256 * 1024 * 1024


def _frame(obj: dict) -> bytes:
    payload = json.dumps(obj).encode("utf-8")
    return struct.pack(">I", len(payload)) + payload


async def _read_msg(reader: asyncio.StreamReader) -> Optional[dict]:
    try:
        head = await reader.readexactly(4)
        n, = struct.unpack(">I", head)
        if n > _MAX_MSG:
            raise PortalError(413, "E_BODY_TOO_LARGE",
                              f"bridge message of {n} bytes exceeds "
                              f"{_MAX_MSG}")
        payload = await reader.readexactly(n)
    except (asyncio.IncompleteReadError, ConnectionError):
        # a peer dying mid-frame is the same as a clean EOF here
        return None
    return json.loads(payload.decode("utf-8"))


class BridgeServer:
    """Dispatcher-side end of the bridge: serves gateway ops over a
    unix-domain socket. Each incoming message becomes its own task, so
    a slow micro-batch never head-of-line-blocks the connection — the
    `id` tags let responses return out of order while each worker's
    HTTP answers stay correctly paired."""

    def __init__(self, gateway, path: str,
                 telemetry: Optional[Telemetry] = None):
        self.gateway = gateway
        self.path = path
        self.telemetry = telemetry
        # latest metrics snapshot per worker pid (see worker_snapshots)
        self._worker_snaps: Dict[int, dict] = {}
        self._server: Optional[asyncio.AbstractServer] = None
        self._conns = set()

    def worker_snapshots(self) -> List[Tuple[int, dict]]:
        """(pid, metrics snapshot) of every worker that has flushed —
        the extra exposition sources `/metrics` aggregates over."""
        return sorted(self._worker_snaps.items())

    async def start(self) -> "BridgeServer":
        self._server = await asyncio.start_unix_server(self._conn,
                                                       path=self.path)
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        # settle per-connection handlers (workers are already dead by
        # now) so loop teardown never reaps a pending task
        for t in list(self._conns):
            t.cancel()
        if self._conns:
            await asyncio.gather(*self._conns, return_exceptions=True)
        self._conns.clear()

    async def _conn(self, reader: asyncio.StreamReader,
                    writer: asyncio.StreamWriter) -> None:
        me = asyncio.current_task()
        self._conns.add(me)
        me.add_done_callback(self._conns.discard)
        lock = asyncio.Lock()          # frame writes stay atomic
        tasks = set()

        async def answer(msg: dict) -> None:
            # ingest piggybacked worker telemetry BEFORE running the
            # op, so a metrics/trace scrape sees the flushing worker's
            # own up-to-the-frame state
            if self.telemetry is not None:
                spans = msg.get("spans")
                if spans:
                    self.telemetry.tracer.record(spans)
            m = msg.get("m")
            if isinstance(m, dict) and "pid" in m:
                self._worker_snaps[int(m["pid"])] = m.get("snap", {})
            out = {"id": msg.get("id")}
            try:
                op = msg.get("op")
                if op not in GATEWAY_OPS:
                    raise PortalError(400, "E_BAD_REQUEST",
                                      f"unknown bridge op {op!r}")
                fn = getattr(self.gateway, op)
                kw = {"trace": msg["trace"]} if "trace" in msg else {}
                out["result"] = await fn(*msg.get("args", []), **kw)
            except PortalError as e:
                out["error"] = e.to_body()["error"]
            except Exception as e:     # noqa: BLE001 — process boundary
                out["error"] = PortalError(
                    500, "E_INTERNAL",
                    f"{type(e).__name__}: {e}").to_body()["error"]
            async with lock:
                writer.write(_frame(out))
                await writer.drain()

        try:
            while True:
                msg = await _read_msg(reader)
                if msg is None:
                    break
                t = asyncio.ensure_future(answer(msg))
                tasks.add(t)
                t.add_done_callback(tasks.discard)
        finally:
            for t in tasks:
                t.cancel()
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass


class _BridgeMethod:
    def __init__(self, client: "BridgeClient", op: str):
        self._client, self._op = client, op

    async def __call__(self, *args, trace: Optional[dict] = None):
        return await self._client.call(self._op, *args, trace=trace)


class BridgeClient:
    """Worker-side gateway: the same duck-typed surface as
    `LocalGateway`, but every call is a length-prefixed JSON message
    over the unix socket. In-flight calls multiplex on one connection;
    message ids pair responses back to their awaiting coroutine.

    The connection self-heals. When the socket drops (dispatcher
    restart, chaos `bridge_drop`), in-flight IDEMPOTENT ops are parked
    and replayed verbatim on the next connection; non-idempotent ops
    (`run`, `reconfigure` — the dispatcher may have applied them before
    dying) fail fast with 503 E_BRIDGE_DOWN so the CLIENT decides
    whether to retry. A background loop redials with capped exponential
    backoff + deterministic jitter; new calls wait up to
    `connect_wait_s` for the link before failing."""

    #: ops safe to resend after a drop — everything except the two that
    #: mutate lane state / weights exactly once per call
    IDEMPOTENT_OPS = frozenset(GATEWAY_OPS) - {"run", "reconfigure"}

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter,
                 telemetry: Optional[Telemetry] = None, *,
                 path: Optional[str] = None,
                 auto_reconnect: bool = True,
                 backoff_base_s: float = 0.05,
                 backoff_cap_s: float = 2.0,
                 connect_wait_s: float = 15.0,
                 seed: int = 0):
        self._reader, self._writer = reader, writer
        self.telemetry = telemetry
        self.path = path
        self.auto_reconnect = bool(auto_reconnect) and path is not None
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.connect_wait_s = float(connect_wait_s)
        self.drops = 0
        self.reconnects = 0
        self._rng = random.Random(seed)
        self._closing = False
        self._connected = asyncio.Event()
        self._connected.set()
        self._m_flushed = 0.0
        self._ids = itertools.count()
        # id -> (future, frame dict) so idempotent frames can replay
        self._waiting: Dict[int, Tuple[asyncio.Future, dict]] = {}
        self._reconnector: Optional[asyncio.Future] = None
        self._pump = asyncio.ensure_future(self._read_loop())
        for op in GATEWAY_OPS:
            setattr(self, op, _BridgeMethod(self, op))

    @classmethod
    async def open(cls, path: str,
                   telemetry: Optional[Telemetry] = None, **kw) \
            -> "BridgeClient":
        reader, writer = await asyncio.open_unix_connection(path)
        return cls(reader, writer, telemetry, path=path, **kw)

    async def _read_loop(self) -> None:
        while True:
            try:
                msg = await _read_msg(self._reader)
            except Exception:          # noqa: BLE001 — treat as drop
                msg = None
            if msg is None:
                self._on_disconnect()
                return
            ent = self._waiting.pop(msg.get("id"), None)
            if ent is None:
                continue
            fut, _ = ent
            if fut.done():
                continue
            if "error" in msg:
                fut.set_exception(
                    PortalError.from_body({"error": msg["error"]}))
            else:
                fut.set_result(msg.get("result"))

    def _down_error(self) -> PortalError:
        return PortalError(503, "E_BRIDGE_DOWN",
                           "dispatcher connection lost — the bridge "
                           "is redialing; retry shortly",
                           retry_after=1.0)

    def _on_disconnect(self) -> None:
        self._connected.clear()
        self.drops += 1
        reconnecting = self.auto_reconnect and not self._closing
        err = self._down_error()
        replay: Dict[int, Tuple[asyncio.Future, dict]] = {}
        for mid, (fut, msg) in self._waiting.items():
            if fut.done():
                continue
            if reconnecting and msg.get("op") in self.IDEMPOTENT_OPS:
                replay[mid] = (fut, msg)
            else:
                # run/reconfigure may have been applied dispatcher-side
                # before the drop — replaying could double-step a lane,
                # so the caller gets the structured 503 instead
                fut.set_exception(err)
        self._waiting = replay
        if reconnecting:
            self._reconnector = asyncio.ensure_future(
                self._reconnect_loop())

    async def _reconnect_loop(self) -> None:
        delay = self.backoff_base_s
        while not self._closing:
            try:
                reader, writer = \
                    await asyncio.open_unix_connection(self.path)
            except OSError:
                await asyncio.sleep(
                    delay + self._rng.uniform(0.0, delay / 2))
                delay = min(delay * 2.0, self.backoff_cap_s)
                continue
            if self._closing:
                writer.close()
                return
            self._reader, self._writer = reader, writer
            self.reconnects += 1
            self._pump = asyncio.ensure_future(self._read_loop())
            self._connected.set()
            # replay parked idempotent frames verbatim (minus the
            # telemetry piggyback, already ingested the first time);
            # ids are connection-local to THIS client so they still
            # pair correctly on the fresh connection
            for mid, (fut, msg) in sorted(self._waiting.items()):
                msg = {k: v for k, v in msg.items()
                       if k not in ("spans", "m")}
                self._waiting[mid] = (fut, msg)
                self._writer.write(_frame(msg))
            try:
                await self._writer.drain()
            except (ConnectionError, OSError):
                pass   # the fresh read loop observes the drop, redials
            return

    async def call(self, op: str, *args,
                   trace: Optional[dict] = None):
        if faults.fire("bridge_drop") and self._writer is not None:
            # chaos: sever the UDS out from under this worker — the
            # read loop sees EOF and the redial path takes over
            self._writer.transport.abort()
        if not self._connected.is_set():
            if not self.auto_reconnect or self._closing:
                raise self._down_error()
            try:
                await asyncio.wait_for(self._connected.wait(),
                                       self.connect_wait_s)
            except asyncio.TimeoutError:
                raise PortalError(
                    503, "E_BRIDGE_DOWN",
                    f"dispatcher unreachable for "
                    f"{self.connect_wait_s:.0f}s", retry_after=1.0)
        mid = next(self._ids)
        fut = asyncio.get_running_loop().create_future()
        msg = {"id": mid, "op": op, "args": list(args)}
        tel = self.telemetry
        span = None
        if tel is not None and tel.tracer.on:
            # the bridge hop is its own span; the dispatcher-side
            # gateway_call nests under it via the forwarded ctx
            span = tel.tracer.span("bridge", ctx=trace, op=op)
            msg["trace"] = span.ctx()
        elif trace is not None:
            msg["trace"] = trace
        if tel is not None:
            # flush finished spans (recorded since the last call) and,
            # throttled — or always for scrape ops — the metrics
            # snapshot; the dispatcher ingests both, which is what
            # makes /metrics aggregated and /trace cross-process
            done = [s.to_dict() for s in tel.tracer.spans()
                    if s.end is not None]
            if done:
                tel.tracer.clear()
                msg["spans"] = done
            now = time.monotonic()
            if op in ("metrics", "healthz", "trace_export") \
                    or now - self._m_flushed > _M_FLUSH_S:
                msg["m"] = {"pid": os.getpid(),
                            "snap": tel.metrics.collect()}
                self._m_flushed = now
        self._waiting[mid] = (fut, msg)
        # write-before-await keeps bridge submission order == the
        # order callers issued calls in (ws streaming relies on it)
        try:
            self._writer.write(_frame(msg))
            await self._writer.drain()
        except (ConnectionError, OSError):
            # drop mid-write: _on_disconnect has already settled (or
            # parked for replay) this future — just await it below
            pass
        try:
            return await fut
        finally:
            if span is not None:
                span.finish()

    async def close(self) -> None:
        self._closing = True
        if self._reconnector is not None:
            self._reconnector.cancel()
            try:
                await self._reconnector
            except (asyncio.CancelledError, Exception):  # noqa: BLE001
                pass
            self._reconnector = None
        self._pump.cancel()
        err = self._down_error()
        for fut, _ in self._waiting.values():
            if not fut.done():
                fut.set_exception(err)
        self._waiting.clear()
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionError, OSError):
            pass


# ------------------------------------------------------------- workers
def _reuseport_socket(host: str, port: int):
    import socket

    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEPORT, 1)
    s.bind((host, port))
    return s


async def _worker_async(host: str, port: int, uds_path: str,
                        auth_spec: Optional[dict],
                        log_json: Optional[str] = None) -> None:
    from repro.portal.http import PortalApp

    telemetry = Telemetry(log_json=log_json)
    gateway = await BridgeClient.open(uds_path, telemetry)
    app = PortalApp(gateway, Authenticator.from_spec(auth_spec),
                    telemetry=telemetry)
    sock = _reuseport_socket(host, port)
    server = await asyncio.start_server(app.handle_conn, sock=sock)
    async with server:
        await server.serve_forever()


def run_worker(host: str, port: int, uds_path: str,
               auth_spec_json: Optional[str] = None,
               log_json: Optional[str] = None) -> None:
    """Entry point of `python -m repro.portal --worker` — one
    front-end process. Blocks until killed by the parent portal."""
    # arm chaos sites from REPRO_FAULTS (no-op when unset) — workers
    # are spawned with the parent portal's env, so one spec governs
    # the whole process tree
    faults.install_from_env()
    spec = json.loads(auth_spec_json) if auth_spec_json else None
    try:
        asyncio.run(_worker_async(host, port, uds_path, spec,
                                  log_json))
    except (KeyboardInterrupt, asyncio.CancelledError):
        pass
