"""Structured portal errors.

Every failure a client can observe over the wire is a `PortalError`:
an HTTP status, a stable machine-readable `code` (the analyzer's E_*
namespace — compile-time diagnostics and transport-time failures speak
one format), a human `message`, and optionally a Retry-After hint
(429/503) and the analyzer's structured findings (400). The JSON body
is the same whether the error was raised in this process or carried
over the worker bridge.

This module is dependency-free on purpose: the bridge WORKER processes
import it without pulling in numpy/jax.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["PortalError"]


class PortalError(Exception):
    """One wire-visible failure. `to_body()` is the canonical JSON
    body; `headers()` contributes Retry-After when a hint is set."""

    def __init__(self, status: int, code: str, message: str, *,
                 retry_after: Optional[float] = None,
                 findings: Optional[dict] = None):
        super().__init__(message)
        self.status = int(status)
        self.code = str(code)
        self.message = str(message)
        self.retry_after = retry_after
        self.findings = findings

    def to_body(self) -> dict:
        err = {"status": self.status, "code": self.code,
               "message": self.message}
        if self.retry_after is not None:
            err["retry_after_s"] = round(float(self.retry_after), 3)
        if self.findings is not None:
            err["findings"] = self.findings
        return {"error": err}

    def headers(self) -> dict:
        if self.retry_after is None:
            return {}
        # Retry-After is delta-seconds, integral, at least 1 — the
        # JSON body carries the precise float hint
        return {"Retry-After": str(max(1, int(round(self.retry_after))))}

    @classmethod
    def from_body(cls, body: dict) -> "PortalError":
        """Rebuild from `to_body()` output (the bridge's error
        round-trip)."""
        err = body.get("error", body)
        return cls(int(err.get("status", 500)),
                   err.get("code", "E_INTERNAL"),
                   err.get("message", "internal error"),
                   retry_after=err.get("retry_after_s"),
                   findings=err.get("findings"))

    def __repr__(self) -> str:
        return (f"PortalError(status={self.status}, code={self.code!r},"
                f" message={self.message!r})")
