"""Websocket transport (RFC 6455) for streaming sessions — stdlib only.

`GET /v1/{model}/stream` upgrades to a websocket whose connection IS a
session: the handshake pins a resident deployment lane (the same
`SlotPool` lane an HTTP session would get), every text frame the
client sends is one spike window, and results stream back IN SUBMISSION
ORDER as their micro-batches resolve — a client may pipeline several
windows without waiting (the server's coalesce rule still runs at most
one window of the lane per batch, so the lane's dynamics equal one
uninterrupted run). Closing the socket releases the lane.

Framing is implemented directly on the handshake primitives the RFC
reduces to — `hashlib.sha1` + `base64` for Sec-WebSocket-Accept and
a ~30-line frame codec (FIN/opcode, 7/16/64-bit lengths, client
masking) — so bridge workers need no third-party dependency.

Wire protocol (text frames, JSON):

  server -> client   {"session": id, "model": m, "window": W}   (hello)
  client -> server   {"counts": [[...]]} | {"events": [[...]]}
                     (one window; optional "tag" echoes back)
  server -> client   {"window": i, "spikes": ..., "membrane": ...,
                      "digest": ...}  or  {"window": i, "error": {...}}
  close frame        drains pending windows, answers them, releases
                     the lane, echoes the close
"""
from __future__ import annotations

import asyncio
import base64
import hashlib
import json
import os
import struct
from typing import Optional, Tuple

from repro.portal.errors import PortalError

__all__ = ["accept_key", "encode_frame", "read_message",
           "handle_stream", "WSClient", "FrameTooBig",
           "MAX_FRAME_BYTES"]

_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"
OP_TEXT, OP_BINARY, OP_CLOSE, OP_PING, OP_PONG = 0x1, 0x2, 0x8, 0x9, 0xA

# same cap as http.MAX_BODY_BYTES (ws.py cannot import http.py — the
# import runs the other way): a frame header may claim a 64-bit
# length, and readexactly() would happily buffer it all, so unbounded
# claims are rejected with close status 1009 before any payload read.
MAX_FRAME_BYTES = 64 * 1024 * 1024
CLOSE_TOO_BIG = 1009            # RFC 6455 7.4.1 "Message Too Big"


class FrameTooBig(Exception):
    """Incoming frame declares a payload over MAX_FRAME_BYTES."""

    def __init__(self, size: int):
        super().__init__(f"websocket frame of {size} bytes exceeds "
                         f"the {MAX_FRAME_BYTES}-byte limit")
        self.size = size


def accept_key(key: str) -> str:
    """Sec-WebSocket-Accept for a client's Sec-WebSocket-Key."""
    digest = hashlib.sha1((key + _GUID).encode("latin-1")).digest()
    return base64.b64encode(digest).decode("latin-1")


def encode_frame(payload: bytes, opcode: int = OP_TEXT,
                 mask: bool = False) -> bytes:
    """One FIN frame. Servers send unmasked; clients MUST mask."""
    head = bytearray([0x80 | opcode])
    n = len(payload)
    mbit = 0x80 if mask else 0
    if n < 126:
        head.append(mbit | n)
    elif n < (1 << 16):
        head.append(mbit | 126)
        head += struct.pack(">H", n)
    else:
        head.append(mbit | 127)
        head += struct.pack(">Q", n)
    if mask:
        key = os.urandom(4)
        head += key
        payload = bytes(b ^ key[i % 4]
                        for i, b in enumerate(payload))
    return bytes(head) + payload


async def _read_frame(reader: asyncio.StreamReader) \
        -> Optional[Tuple[int, bool, bytes]]:
    """(opcode, fin, payload), None on EOF or mid-frame disconnect
    (abrupt client exits are routine, not errors), or `FrameTooBig`
    for a length claim over MAX_FRAME_BYTES."""
    try:
        b1, b2 = await reader.readexactly(2)
        fin, opcode = bool(b1 & 0x80), b1 & 0x0F
        masked, n = bool(b2 & 0x80), b2 & 0x7F
        if n == 126:
            n, = struct.unpack(">H", await reader.readexactly(2))
        elif n == 127:
            n, = struct.unpack(">Q", await reader.readexactly(8))
        if n > MAX_FRAME_BYTES:
            raise FrameTooBig(n)
        key = await reader.readexactly(4) if masked else None
        payload = await reader.readexactly(n) if n else b""
    except (asyncio.IncompleteReadError, ConnectionError):
        return None
    if key:
        payload = bytes(b ^ key[i % 4]
                        for i, b in enumerate(payload))
    return opcode, fin, payload


async def read_message(reader: asyncio.StreamReader,
                       writer: asyncio.StreamWriter) \
        -> Optional[Tuple[int, bytes]]:
    """Next complete data/close message, reassembling fragments and
    answering pings inline. None on EOF; raises `FrameTooBig` when a
    frame claims more than MAX_FRAME_BYTES (caller closes with 1009)."""
    opcode, buf = None, bytearray()
    while True:
        frame = await _read_frame(reader)
        if frame is None:
            return None
        op, fin, payload = frame
        if op == OP_PING:
            writer.write(encode_frame(payload, OP_PONG))
            continue
        if op == OP_PONG:
            continue
        if op == OP_CLOSE:
            return OP_CLOSE, payload
        if op in (OP_TEXT, OP_BINARY):
            opcode, buf = op, bytearray(payload)
        elif opcode is not None:      # continuation
            buf += payload
        else:
            continue
        if fin:
            return opcode, bytes(buf)


def _handshake_bytes(key: str) -> bytes:
    return ("HTTP/1.1 101 Switching Protocols\r\n"
            "Upgrade: websocket\r\n"
            "Connection: Upgrade\r\n"
            f"Sec-WebSocket-Accept: {accept_key(key)}\r\n"
            "\r\n").encode("latin-1")


# --------------------------------------------------------------- server
async def handle_stream(app, req, reader: asyncio.StreamReader,
                        writer: asyncio.StreamWriter, model: str,
                        state) -> None:
    """Serve one streaming-session connection (called by
    `PortalApp._websocket` after routing + auth)."""
    from repro.portal.http import http_response   # no cycle at import

    key = req.headers.get("sec-websocket-key")
    if not key:
        e = PortalError(400, "E_BAD_REQUEST",
                        "websocket upgrade without Sec-WebSocket-Key")
        writer.write(http_response(e.status, e.to_body(),
                                   keep_alive=False))
        await writer.drain()
        return
    try:
        hello = await app.gateway.open_session(model)
    except PortalError as e:
        # lane exhaustion / unknown model is an ordinary HTTP error,
        # not a broken socket
        writer.write(http_response(e.status, e.to_body(),
                                   headers=e.headers(),
                                   keep_alive=False))
        await writer.drain()
        return
    sid = hello["session"]
    writer.write(_handshake_bytes(key))
    writer.write(encode_frame(json.dumps(hello).encode("utf-8")))
    await writer.drain()

    # one trace per stream: every window span (and its downstream
    # bridge/queue/dispatch spans) nests under this root
    tel = app.tel
    root = tel.tracer.span(
        "ws_stream",
        trace_id=req.headers.get("x-trace-id") or None,
        model=model, session=sid)

    pending: asyncio.Queue = asyncio.Queue()

    async def window_task(payload: dict) -> dict:
        with app.auth.admit(state):
            payload = dict(payload)
            payload["session"] = sid
            span = tel.tracer.span("ws_window", ctx=root.ctx(),
                                   model=model)
            try:
                out = await app.gateway.run(model, payload,
                                            trace=span.ctx())
            except PortalError as e:
                span.finish(error=e.code)
                raise
            span.finish()
            return out

    close_payload = b""

    async def produce() -> None:
        # the try/finally guarantees the None sentinel even if a read
        # raises: a producer that dies silently would leave the
        # consumer blocked on pending.get() forever, and the finally
        # below (lane release) would never run — the lane would leak.
        nonlocal close_payload
        idx = 0
        try:
            while True:
                try:
                    msg = await read_message(reader, writer)
                except FrameTooBig as e:
                    # answered by the consumer's close frame, AFTER
                    # every already-pipelined window — no data frame
                    # ever follows the close
                    close_payload = (struct.pack(">H", CLOSE_TOO_BIG)
                                     + str(e).encode("utf-8")[:100])
                    break
                if msg is None or msg[0] == OP_CLOSE:
                    break
                try:
                    payload = json.loads(msg[1].decode("utf-8"))
                    if not isinstance(payload, dict):
                        raise ValueError("window message must be a "
                                         "JSON object")
                except (ValueError, UnicodeDecodeError) as e:
                    err = PortalError(400, "E_BAD_JSON",
                                      f"bad window message: {e}")
                    fut = asyncio.get_running_loop().create_future()
                    fut.set_exception(err)
                    await pending.put((idx, None, fut))
                else:
                    tag = payload.pop("tag", None)
                    # the task starts now — submission order IS frame
                    # order
                    task = asyncio.ensure_future(window_task(payload))
                    await pending.put((idx, tag, task))
                idx += 1
        finally:
            pending.put_nowait(None)

    producer = asyncio.ensure_future(produce())
    try:
        while True:
            item = await pending.get()
            if item is None:
                break
            idx, tag, task = item
            out = {"window": idx}
            if tag is not None:
                out["tag"] = tag
            try:
                out.update(await task)
            except PortalError as e:
                out["error"] = e.to_body()["error"]
            except Exception as e:        # noqa: BLE001 — wire boundary
                out["error"] = PortalError(
                    500, "E_INTERNAL",
                    f"{type(e).__name__}: {e}").to_body()["error"]
            if tel.log.enabled:
                err = out.get("error")
                tel.log.request(
                    trace_id=root.trace_id,
                    token=state.name if state is not None else "",
                    model=model, op="ws_window",
                    status=err.get("status", 500) if err else 200,
                    code=err.get("code") if err else None,
                    window=idx,
                    **{k: out[k] for k in
                       ("bucket", "batch_size", "queue_wait_ms",
                        "dispatch_ms") if k in out})
            writer.write(encode_frame(json.dumps(out).encode("utf-8")))
            await writer.drain()
        writer.write(encode_frame(close_payload, OP_CLOSE))
        await writer.drain()
    except (ConnectionError, OSError):
        pass
    finally:
        root.finish()
        producer.cancel()
        try:
            await app.gateway.close_session(model, sid)
        except PortalError:
            pass


# --------------------------------------------------------------- client
class WSClient:
    """Synchronous websocket client for the streaming endpoint — what
    the tests, the bench, and `examples/serve_snn.py --portal` drive
    the portal with (also a reference for external clients).

        c = WSClient("127.0.0.1", port, "demo", token="s3cret")
        c.send_window(counts=window)          # pipeline as many as
        res = c.recv()                        # you like; results come
        c.close()                             # back in order
    """

    def __init__(self, host: str, port: int, model: str,
                 token: Optional[str] = None, timeout: float = 120.0):
        import socket

        self.sock = socket.create_connection((host, port),
                                             timeout=timeout)
        self._buf = b""
        key = base64.b64encode(os.urandom(16)).decode("latin-1")
        lines = [f"GET /v1/{model}/stream HTTP/1.1",
                 f"Host: {host}:{port}",
                 "Upgrade: websocket", "Connection: Upgrade",
                 f"Sec-WebSocket-Key: {key}",
                 "Sec-WebSocket-Version: 13"]
        if token:
            lines.append(f"Authorization: Bearer {token}")
        self.sock.sendall(("\r\n".join(lines) + "\r\n\r\n")
                          .encode("latin-1"))
        status, headers, body = self._read_http_response()
        if status != 101:
            self.sock.close()
            raise PortalError.from_body(
                json.loads(body.decode("utf-8") or "{}"))
        if headers.get("sec-websocket-accept") != accept_key(key):
            self.sock.close()
            raise PortalError(502, "E_HANDSHAKE",
                              "bad Sec-WebSocket-Accept from server")
        self.hello = self.recv()
        self.session = self.hello["session"]

    # -------------------------------------------------- raw transport
    def _read_exact(self, n: int) -> bytes:
        while len(self._buf) < n:
            chunk = self.sock.recv(65536)
            if not chunk:
                raise ConnectionError("websocket peer closed")
            self._buf += chunk
        out, self._buf = self._buf[:n], self._buf[n:]
        return out

    def _read_http_response(self):
        while b"\r\n\r\n" not in self._buf:
            chunk = self.sock.recv(65536)
            if not chunk:
                raise ConnectionError("peer closed during handshake")
            self._buf += chunk
        head, self._buf = self._buf.split(b"\r\n\r\n", 1)
        lines = head.decode("latin-1").split("\r\n")
        status = int(lines[0].split()[1])
        headers = {}
        for ln in lines[1:]:
            name, _, value = ln.partition(":")
            headers[name.strip().lower()] = value.strip()
        body = b""
        n = int(headers.get("content-length", "0") or 0)
        if n:
            body = self._read_exact(n)
        return status, headers, body

    def _read_frame(self):
        b1, b2 = self._read_exact(2)
        opcode, n = b1 & 0x0F, b2 & 0x7F
        if n == 126:
            n, = struct.unpack(">H", self._read_exact(2))
        elif n == 127:
            n, = struct.unpack(">Q", self._read_exact(8))
        payload = self._read_exact(n) if n else b""
        return opcode, payload

    # ------------------------------------------------------- protocol
    def send_window(self, counts=None, events=None, seed=None,
                    tag=None) -> None:
        """Submit one spike window (does not wait for the result)."""
        msg = {}
        if counts is not None:
            msg["counts"] = [[int(x) for x in row] for row in counts]
        if events is not None:
            msg["events"] = [[int(x) for x in step] for step in events]
        if seed is not None:
            msg["seed"] = int(seed)
        if tag is not None:
            msg["tag"] = tag
        self.sock.sendall(encode_frame(
            json.dumps(msg).encode("utf-8"), mask=True))

    def recv(self) -> dict:
        """Next in-order server message; raises `PortalError` if the
        window failed."""
        while True:
            opcode, payload = self._read_frame()
            if opcode == OP_CLOSE:
                raise ConnectionError("server closed the stream")
            if opcode == OP_PING:
                self.sock.sendall(encode_frame(payload, OP_PONG,
                                               mask=True))
                continue
            if opcode not in (OP_TEXT, OP_BINARY):
                continue
            out = json.loads(payload.decode("utf-8"))
            if "error" in out:
                raise PortalError.from_body({"error": out["error"]})
            return out

    def close(self) -> None:
        """Send the close frame and wait for the server's echo (which
        arrives only after every pipelined window was answered)."""
        try:
            self.sock.sendall(encode_frame(b"", OP_CLOSE, mask=True))
            while True:
                opcode, _ = self._read_frame()
                if opcode == OP_CLOSE:
                    break
        except (ConnectionError, OSError):
            pass
        finally:
            self.sock.close()
