"""`python -m repro.portal` — serve a resident model over localhost.

Builds the same random recurrent SNN as `python -m repro.serve`, makes
it resident in a `SpikeServer`, and opens the web portal on top:

    PYTHONPATH=src python -m repro.portal --port 8787 --workers 4 \
        --token s3cret:50:16:8

    curl -s localhost:8787/healthz
    curl -s -X POST localhost:8787/v1/demo/run \
        -H 'Authorization: Bearer s3cret' \
        -d '{"events": [[0, 1], [2], []]}'

Runs until SIGINT/SIGTERM, then drains: the signal handler calls
`SpikeServer.shutdown(drain=True)`, so every queued request is
answered before the process exits.

The hidden `--worker` mode is the entry point of spawned bridge
front-end processes (see `repro.portal.bridge`); it imports no
numpy/jax.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading


def _worker_mode(argv) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.portal --worker")
    ap.add_argument("--host", required=True)
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--uds", required=True)
    ap.add_argument("--auth-spec", default=None)
    ap.add_argument("--log-json", default=None)
    args = ap.parse_args(argv)

    from repro.portal.bridge import run_worker

    run_worker(args.host, args.port, args.uds, args.auth_spec,
               args.log_json)
    return 0


def _parse_token(spec: str):
    """`secret[:rate[:burst[:max_inflight]]]` -> (secret, TokenQuota)."""
    from repro.portal.auth import TokenQuota

    parts = spec.split(":")
    secret = parts[0]
    if not secret:
        raise SystemExit(f"empty token in --token {spec!r}")
    rate = float(parts[1]) if len(parts) > 1 else 50.0
    burst = int(parts[2]) if len(parts) > 2 else max(int(rate), 1)
    inflight = int(parts[3]) if len(parts) > 3 else 8
    return secret, TokenQuota(rate=rate, burst=burst,
                              max_inflight=inflight)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "--worker":
        return _worker_mode(argv[1:])

    ap = argparse.ArgumentParser(prog="python -m repro.portal")
    ap.add_argument("--backend", default="engine",
                    choices=["simulator", "engine", "hiaer", "mesh"])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = serve in-process; N = spawn N bridged "
                         "front-end worker processes (token quotas "
                         "are then enforced per worker — up to N x "
                         "the configured limits; /metrics totals are "
                         "bridge-aggregated across workers, with "
                         "per-worker breakdown under *_by_worker)")
    ap.add_argument("--model", default="demo",
                    help="resident model name (the {model} in /v1/"
                         "{model}/run)")
    ap.add_argument("--axons", type=int, default=16)
    ap.add_argument("--neurons", type=int, default=64)
    ap.add_argument("--window", type=int, default=8)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--wait-ms", type=float, default=5.0)
    ap.add_argument("--max-pending", type=int, default=256,
                    help="ingestion-queue bound; beyond it requests "
                         "shed with 503 + Retry-After")
    ap.add_argument("--token", action="append", default=[],
                    metavar="SECRET[:RATE[:BURST[:INFLIGHT]]]",
                    help="add a bearer token (repeatable); no --token "
                         "= open portal")
    ap.add_argument("--log-json", default=None, metavar="PATH|-",
                    help="write one JSON line per request to PATH "
                         "('-' = stdout); off by default")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable span recording and metric updates "
                         "(tracing/metrics are on by default)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="arm chaos sites in THIS process and every "
                         "spawned worker (exported as REPRO_FAULTS), "
                         "e.g. 'worker_exit@3;bridge_drop%%0.02'")
    ap.add_argument("--faults-seed", type=int, default=0)
    ap.add_argument("--faults-log", default=None, metavar="PATH",
                    help="append one NDJSON line per fired fault "
                         "(line-atomic across processes)")
    args = ap.parse_args(argv)

    import os

    from repro import faults
    from repro.obs import Telemetry

    if args.faults:
        # export so bridge workers (spawned with this env) arm the
        # same plan; their per-site hit counters are process-local
        os.environ["REPRO_FAULTS"] = args.faults
        os.environ["REPRO_FAULTS_SEED"] = str(args.faults_seed)
        if args.faults_log:
            os.environ["REPRO_FAULTS_LOG"] = args.faults_log
    faults.install_from_env()
    from repro.portal.gateway import Portal
    from repro.serve import SpikeServer
    from repro.serve.__main__ import demo_spec
    from repro.core.compile import compile_spec

    compiled = compile_spec(demo_spec(args.axons, args.neurons),
                            target=args.backend)
    tel = Telemetry(on=not args.no_telemetry, log_json=args.log_json)
    srv = SpikeServer(max_batch=args.max_batch,
                      max_wait_ms=args.wait_ms,
                      max_pending=args.max_pending,
                      telemetry=tel)
    srv.add_model(args.model, compiled, window=args.window,
                  n_sessions=args.sessions, seed=0)
    tokens = dict(_parse_token(t) for t in args.token) or None

    stop = threading.Event()

    def _signal(signum, frame):
        print(f"\nsignal {signum}: draining and shutting down ...",
              flush=True)
        stop.set()

    signal.signal(signal.SIGINT, _signal)
    signal.signal(signal.SIGTERM, _signal)

    srv.start()
    portal = Portal(srv, host=args.host, port=args.port,
                    workers=args.workers, tokens=tokens)
    portal.start()
    mode = (f"{args.workers} bridged workers" if args.workers
            else "in-process")
    print(f"portal serving model {args.model!r} "
          f"({args.backend}, {args.axons} axons, {args.neurons} "
          f"neurons, window {args.window}) at {portal.url}  [{mode}]")
    print(f"  curl -s {portal.url}/healthz")
    auth = f" -H 'Authorization: Bearer {args.token[0].split(':')[0]}'"\
        if args.token else ""
    print(f"  curl -s -X POST {portal.url}/v1/{args.model}/run{auth} "
          f"-d '{{\"events\": [[0, 1], [2]]}}'")
    try:
        stop.wait()
    finally:
        portal.stop()
        # drain: every queued request is resolved before exit — no
        # client hangs on a dead socket
        srv.shutdown(drain=True)
        print("portal stopped; dispatcher drained.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
