"""Minimal HTTP/1.1 front end on `asyncio` streams — stdlib only.

The transport half of the paper's web portal: a deliberately small
HTTP server (no framework, no threads — one coroutine per connection,
keep-alive, Content-Length bodies) that exposes the serving tier over
the network. Every handler goes through the same three steps —
authenticate, charge quota, forward to the gateway — and every failure
is a structured `PortalError` JSON body.

Routes (all bodies JSON):

  GET  /healthz                         liveness + resident models
  GET  /metrics                         server stats + per-token counters
  POST /v1/{model}/run                  one spike window -> spikes/digest
  POST /v1/{model}/reconfigure          write_synapses barrier
  POST /v1/{model}/session              open a resident-lane session
  GET  /v1/{model}/session/{id}         session membrane digest
  POST /v1/{model}/session/{id}/reset   lane back to V=0
  DELETE /v1/{model}/session/{id}       release the lane
  GET  /v1/{model}/stream               RFC 6455 websocket upgrade
                                        (streaming session; repro.portal.ws)

The `gateway` is duck-typed (`LocalGateway` in-process over a
`SpikeServer`, `BridgeClient` in a front-end worker forwarding over
the unix-socket bridge), which is what lets accept/parse/auth scale
across processes independently of the single dispatcher.
"""
from __future__ import annotations

import asyncio
import json
import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.portal import ws as _ws
from repro.portal.auth import Authenticator
from repro.portal.errors import PortalError

__all__ = ["HTTPRequest", "PortalApp", "read_request", "http_response"]

MAX_HEADER_BYTES = 64 * 1024
MAX_BODY_BYTES = 64 * 1024 * 1024

_REASONS = {200: "OK", 101: "Switching Protocols", 400: "Bad Request",
            401: "Unauthorized", 404: "Not Found",
            405: "Method Not Allowed", 408: "Request Timeout",
            413: "Payload Too Large", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}


@dataclass
class HTTPRequest:
    method: str
    target: str
    version: str
    headers: Dict[str, str] = field(default_factory=dict)
    body: bytes = b""

    @property
    def path(self) -> str:
        return self.target.split("?", 1)[0]

    def json(self) -> dict:
        if not self.body:
            return {}
        try:
            obj = json.loads(self.body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise PortalError(400, "E_BAD_JSON",
                              f"request body is not valid JSON: {e}")
        if not isinstance(obj, dict):
            raise PortalError(400, "E_BAD_JSON",
                              "request body must be a JSON object")
        return obj

    @property
    def keep_alive(self) -> bool:
        conn = self.headers.get("connection", "").lower()
        if self.version == "HTTP/1.0":
            return conn == "keep-alive"
        return "close" not in conn

    def wants_websocket(self) -> bool:
        return ("websocket" in self.headers.get("upgrade", "").lower()
                and "upgrade" in self.headers.get("connection",
                                                  "").lower())


async def read_request(reader: asyncio.StreamReader) \
        -> Optional[HTTPRequest]:
    """Parse one request off the stream; None on clean EOF. Raises
    `PortalError` on malformed or oversized input."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise PortalError(400, "E_BAD_REQUEST",
                          "connection closed mid-request")
    except asyncio.LimitOverrunError:
        raise PortalError(413, "E_HEADERS_TOO_LARGE",
                          f"request head exceeds {MAX_HEADER_BYTES} "
                          f"bytes")
    if len(head) > MAX_HEADER_BYTES:
        raise PortalError(413, "E_HEADERS_TOO_LARGE",
                          f"request head exceeds {MAX_HEADER_BYTES} "
                          f"bytes")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split()
    if len(parts) != 3 or not parts[2].startswith("HTTP/"):
        raise PortalError(400, "E_BAD_REQUEST",
                          f"malformed request line: {lines[0]!r}")
    req = HTTPRequest(method=parts[0].upper(), target=parts[1],
                      version=parts[2])
    for ln in lines[1:]:
        if not ln:
            continue
        name, sep, value = ln.partition(":")
        if not sep:
            raise PortalError(400, "E_BAD_REQUEST",
                              f"malformed header line: {ln!r}")
        req.headers[name.strip().lower()] = value.strip()
    length = req.headers.get("content-length", "0")
    try:
        n = int(length)
    except ValueError:
        raise PortalError(400, "E_BAD_REQUEST",
                          f"bad Content-Length: {length!r}")
    if n > MAX_BODY_BYTES:
        raise PortalError(413, "E_BODY_TOO_LARGE",
                          f"body of {n} bytes exceeds the "
                          f"{MAX_BODY_BYTES}-byte limit")
    if n:
        req.body = await reader.readexactly(n)
    return req


def http_response(status: int, body: dict, *,
                  headers: Optional[Dict[str, str]] = None,
                  keep_alive: bool = True) -> bytes:
    payload = json.dumps(body).encode("utf-8")
    lines = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
             "Content-Type: application/json",
             f"Content-Length: {len(payload)}",
             f"Connection: {'keep-alive' if keep_alive else 'close'}"]
    for k, v in (headers or {}).items():
        lines.append(f"{k}: {v}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + payload


class PortalApp:
    """Route table + per-connection loop. One instance serves every
    connection of one worker (or of the in-process portal thread)."""

    def __init__(self, gateway, auth: Optional[Authenticator] = None):
        self.gateway = gateway
        self.auth = auth or Authenticator(None)

    # ------------------------------------------------------ connection
    async def handle_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    req = await read_request(reader)
                except PortalError as e:
                    writer.write(http_response(
                        e.status, e.to_body(), headers=e.headers(),
                        keep_alive=False))
                    await writer.drain()
                    break
                if req is None:
                    break
                if req.wants_websocket():
                    await self._websocket(req, reader, writer)
                    break
                status, body, headers = await self.dispatch(req)
                writer.write(http_response(status, body,
                                           headers=headers,
                                           keep_alive=req.keep_alive))
                await writer.drain()
                if not req.keep_alive:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # ------------------------------------------------------- dispatch
    async def dispatch(self, req: HTTPRequest) \
            -> Tuple[int, dict, Dict[str, str]]:
        try:
            return 200, await self._route(req), {}
        except PortalError as e:
            return e.status, e.to_body(), e.headers()
        except Exception as e:     # noqa: BLE001 — wire boundary
            err = PortalError(500, "E_INTERNAL",
                              f"{type(e).__name__}: {e}")
            return err.status, err.to_body(), err.headers()

    async def _route(self, req: HTTPRequest) -> dict:
        path, method = req.path, req.method
        if path == "/healthz":
            self._need(method, "GET")
            out = await self.gateway.healthz()
            # which front-end process answered (the dispatcher's own
            # pid rides in `pid`) — Portal._wait_ready polls this to
            # confirm every SO_REUSEPORT worker is accepting
            out["worker_pid"] = os.getpid()
            return out
        if path == "/metrics":
            self._need(method, "GET")
            stats = await self.gateway.stats()
            return {"server": stats, "clients": self.auth.metrics()}
        seg = [s for s in path.split("/") if s]
        if len(seg) >= 3 and seg[0] == "v1":
            return await self._v1(req, seg[1], seg[2:])
        raise PortalError(404, "E_NO_ROUTE",
                          f"no route for {method} {path}")

    async def _v1(self, req: HTTPRequest, model: str, rest) -> dict:
        state = self.auth.authenticate(req.headers)
        method = req.method
        if rest == ["run"]:
            self._need(method, "POST")
            with self.auth.admit(state):
                return await self.gateway.run(model, req.json())
        if rest == ["reconfigure"]:
            self._need(method, "POST")
            with self.auth.admit(state):
                return await self.gateway.reconfigure(model,
                                                      req.json())
        if rest == ["session"]:
            self._need(method, "POST")
            return await self.gateway.open_session(model)
        if len(rest) >= 2 and rest[0] == "session":
            sid = self._int(rest[1])
            if len(rest) == 2 and method == "GET":
                return await self.gateway.session_info(model, sid)
            if len(rest) == 2 and method == "DELETE":
                return await self.gateway.close_session(model, sid)
            if rest[2:] == ["reset"]:
                self._need(method, "POST")
                return await self.gateway.reset_session(model, sid)
        raise PortalError(404, "E_NO_ROUTE",
                          f"no route for {method} {req.path}")

    async def _websocket(self, req: HTTPRequest,
                         reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        """GET /v1/{model}/stream — auth happens BEFORE the 101, so a
        bad token is an ordinary HTTP 401, not a broken socket."""
        seg = [s for s in req.path.split("/") if s]
        try:
            if len(seg) != 3 or seg[0] != "v1" or seg[2] != "stream":
                raise PortalError(404, "E_NO_ROUTE",
                                  f"no websocket route for {req.path}")
            state = self.auth.authenticate(req.headers)
        except PortalError as e:
            writer.write(http_response(e.status, e.to_body(),
                                       headers=e.headers(),
                                       keep_alive=False))
            await writer.drain()
            return
        await _ws.handle_stream(self, req, reader, writer, seg[1],
                                state)

    # ------------------------------------------------------- helpers
    @staticmethod
    def _need(method: str, expected: str) -> None:
        if method != expected:
            raise PortalError(405, "E_METHOD",
                              f"use {expected} for this route, not "
                              f"{method}")

    @staticmethod
    def _int(s: str) -> int:
        try:
            return int(s)
        except ValueError:
            raise PortalError(400, "E_BAD_REQUEST",
                              f"session id must be an integer, got "
                              f"{s!r}")
