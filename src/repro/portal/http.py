"""Minimal HTTP/1.1 front end on `asyncio` streams — stdlib only.

The transport half of the paper's web portal: a deliberately small
HTTP server (no framework, no threads — one coroutine per connection,
keep-alive, Content-Length bodies) that exposes the serving tier over
the network. Every handler goes through the same three steps —
authenticate, charge quota, forward to the gateway — and every failure
is a structured `PortalError` JSON body.

Routes (all bodies JSON):

  GET  /healthz                         liveness + resident models
  GET  /metrics                         server stats + per-token counters
  POST /v1/{model}/run                  one spike window -> spikes/digest
  POST /v1/{model}/reconfigure          write_synapses barrier
  POST /v1/{model}/session              open a resident-lane session
  GET  /v1/{model}/session/{id}         session membrane digest
  POST /v1/{model}/session/{id}/reset   lane back to V=0
  DELETE /v1/{model}/session/{id}       release the lane
  GET  /v1/{model}/stream               RFC 6455 websocket upgrade
                                        (streaming session; repro.portal.ws)

The `gateway` is duck-typed (`LocalGateway` in-process over a
`SpikeServer`, `BridgeClient` in a front-end worker forwarding over
the unix-socket bridge), which is what lets accept/parse/auth scale
across processes independently of the single dispatcher.
"""
from __future__ import annotations

import asyncio
import json
import os
import time
import urllib.parse
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

from repro import faults
from repro.obs import Telemetry
from repro.portal import ws as _ws
from repro.portal.auth import Authenticator
from repro.portal.errors import PortalError

__all__ = ["HTTPRequest", "PortalApp", "RawResult", "read_request",
           "http_response"]

MAX_HEADER_BYTES = 64 * 1024
MAX_BODY_BYTES = 64 * 1024 * 1024

_REASONS = {200: "OK", 101: "Switching Protocols", 400: "Bad Request",
            401: "Unauthorized", 404: "Not Found",
            405: "Method Not Allowed", 408: "Request Timeout",
            413: "Payload Too Large", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}


@dataclass
class HTTPRequest:
    method: str
    target: str
    version: str
    headers: Dict[str, str] = field(default_factory=dict)
    body: bytes = b""
    # telemetry, filled in by dispatch/_v1: the request's span
    # propagation ctx and the quota label of its token (never the
    # secret)
    trace: Optional[dict] = None
    token_label: str = ""

    @property
    def path(self) -> str:
        return self.target.split("?", 1)[0]

    @property
    def query(self) -> Dict[str, str]:
        """Last-wins query parameters of the request target."""
        if "?" not in self.target:
            return {}
        qs = urllib.parse.parse_qsl(self.target.split("?", 1)[1],
                                    keep_blank_values=True)
        return dict(qs)

    def json(self) -> dict:
        if not self.body:
            return {}
        try:
            obj = json.loads(self.body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise PortalError(400, "E_BAD_JSON",
                              f"request body is not valid JSON: {e}")
        if not isinstance(obj, dict):
            raise PortalError(400, "E_BAD_JSON",
                              "request body must be a JSON object")
        return obj

    @property
    def keep_alive(self) -> bool:
        conn = self.headers.get("connection", "").lower()
        if self.version == "HTTP/1.0":
            return conn == "keep-alive"
        return "close" not in conn

    def wants_websocket(self) -> bool:
        return ("websocket" in self.headers.get("upgrade", "").lower()
                and "upgrade" in self.headers.get("connection",
                                                  "").lower())


async def read_request(reader: asyncio.StreamReader) \
        -> Optional[HTTPRequest]:
    """Parse one request off the stream; None on clean EOF. Raises
    `PortalError` on malformed or oversized input."""
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise PortalError(400, "E_BAD_REQUEST",
                          "connection closed mid-request")
    except asyncio.LimitOverrunError:
        raise PortalError(413, "E_HEADERS_TOO_LARGE",
                          f"request head exceeds {MAX_HEADER_BYTES} "
                          f"bytes")
    if len(head) > MAX_HEADER_BYTES:
        raise PortalError(413, "E_HEADERS_TOO_LARGE",
                          f"request head exceeds {MAX_HEADER_BYTES} "
                          f"bytes")
    lines = head.decode("latin-1").split("\r\n")
    parts = lines[0].split()
    if len(parts) != 3 or not parts[2].startswith("HTTP/"):
        raise PortalError(400, "E_BAD_REQUEST",
                          f"malformed request line: {lines[0]!r}")
    req = HTTPRequest(method=parts[0].upper(), target=parts[1],
                      version=parts[2])
    for ln in lines[1:]:
        if not ln:
            continue
        name, sep, value = ln.partition(":")
        if not sep:
            raise PortalError(400, "E_BAD_REQUEST",
                              f"malformed header line: {ln!r}")
        req.headers[name.strip().lower()] = value.strip()
    length = req.headers.get("content-length", "0")
    try:
        n = int(length)
    except ValueError:
        raise PortalError(400, "E_BAD_REQUEST",
                          f"bad Content-Length: {length!r}")
    if n > MAX_BODY_BYTES:
        raise PortalError(413, "E_BODY_TOO_LARGE",
                          f"body of {n} bytes exceeds the "
                          f"{MAX_BODY_BYTES}-byte limit")
    if n:
        req.body = await reader.readexactly(n)
    return req


@dataclass
class RawResult:
    """A non-JSON (or non-200-JSON) route result — the Prometheus
    text exposition, or a health body that must ride a 503."""
    status: int
    content_type: str
    payload: bytes


def http_response(status: int, body: Union[dict, bytes, bytearray], *,
                  headers: Optional[Dict[str, str]] = None,
                  keep_alive: bool = True) -> bytes:
    hdrs = dict(headers or {})
    ctype = hdrs.pop("Content-Type", "application/json")
    payload = bytes(body) if isinstance(body, (bytes, bytearray)) \
        else json.dumps(body).encode("utf-8")
    lines = [f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}",
             f"Content-Type: {ctype}",
             f"Content-Length: {len(payload)}",
             f"Connection: {'keep-alive' if keep_alive else 'close'}"]
    for k, v in hdrs.items():
        lines.append(f"{k}: {v}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + payload


class PortalApp:
    """Route table + per-connection loop. One instance serves every
    connection of one worker (or of the in-process portal thread)."""

    def __init__(self, gateway, auth: Optional[Authenticator] = None,
                 telemetry: Optional[Telemetry] = None):
        self.gateway = gateway
        self.auth = auth or Authenticator(None)
        self.tel = telemetry if telemetry is not None else Telemetry()
        mreg = self.tel.metrics
        self._m_http = mreg.counter(
            "repro_http_requests_total",
            "HTTP requests by method and status",
            ("method", "status"))
        self._m_http_lat = mreg.histogram(
            "repro_http_latency_ms",
            "Wall-clock HTTP request latency in milliseconds")
        self._m_tok_admit = mreg.counter(
            "repro_token_admitted_total",
            "Requests admitted per token quota", ("token",))
        self._m_tok_reject = mreg.counter(
            "repro_token_rejected_total",
            "Requests rejected per token quota",
            ("token", "reason"))
        self._tok_last: Dict = {}
        mreg.register_callback(self._scrape_auth)

    def _scrape_auth(self, mreg) -> None:
        """Per-token quota counters, read at collect time from the
        authenticator's cumulative tallies (delta-tracked so they
        expose as true Prometheus counters and SUM correctly across
        worker snapshots)."""
        g_inflight = mreg.gauge("repro_token_inflight",
                                "Requests currently in flight per "
                                "token", ("token",))
        for label, m in self.auth.metrics().items():
            g_inflight.set(m["inflight"], token=label)
            for fld, inc in (
                    ("admitted", lambda n: self._m_tok_admit.inc(
                        n, token=label)),
                    ("rejected_rate", lambda n: self._m_tok_reject.inc(
                        n, token=label, reason="rate")),
                    ("rejected_inflight",
                     lambda n: self._m_tok_reject.inc(
                         n, token=label, reason="inflight"))):
                last = self._tok_last.get((label, fld), 0)
                if m[fld] > last:
                    inc(m[fld] - last)
                    self._tok_last[(label, fld)] = m[fld]

    # ------------------------------------------------------ connection
    async def handle_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    req = await read_request(reader)
                except PortalError as e:
                    writer.write(http_response(
                        e.status, e.to_body(), headers=e.headers(),
                        keep_alive=False))
                    await writer.drain()
                    break
                if req is None:
                    break
                if req.wants_websocket():
                    await self._websocket(req, reader, writer)
                    break
                status, body, headers = await self.dispatch(req)
                writer.write(http_response(status, body,
                                           headers=headers,
                                           keep_alive=req.keep_alive))
                await writer.drain()
                if not req.keep_alive:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # ------------------------------------------------------- dispatch
    async def dispatch(self, req: HTTPRequest) \
            -> Tuple[int, Union[dict, bytes], Dict[str, str]]:
        # root span of the request's trace: honour an X-Trace-Id the
        # client (or an upstream proxy) supplied, mint one otherwise;
        # the id is echoed back so clients can fetch /trace?trace_id=
        span = self.tel.tracer.span(
            "http_request",
            trace_id=req.headers.get("x-trace-id") or None,
            method=req.method, path=req.path)
        req.trace = span.ctx()
        headers: Dict[str, str] = {}
        try:
            out = await self._route(req)
            if isinstance(out, RawResult):
                status, body = out.status, out.payload
                headers["Content-Type"] = out.content_type
            else:
                status, body = 200, out
        except PortalError as e:
            status, body, headers = e.status, e.to_body(), e.headers()
        except Exception as e:     # noqa: BLE001 — wire boundary
            err = PortalError(500, "E_INTERNAL",
                              f"{type(e).__name__}: {e}")
            status, body, headers = err.status, err.to_body(), \
                err.headers()
        self._observe(req, span, status, body)
        if span.trace_id:
            headers["X-Trace-Id"] = span.trace_id
        return status, body, headers

    def _observe(self, req: HTTPRequest, span, status: int,
                 body) -> None:
        """Finish the root span, count the request, and emit its JSON
        log line (one per request, `--log-json`)."""
        span.finish(status=status)
        if self.tel.on:
            self._m_http.inc(method=req.method, status=str(status))
            self._m_http_lat.observe(span.duration_ms)
        if not self.tel.log.enabled:
            return
        err = body.get("error") if isinstance(body, dict) else None
        seg = [s for s in req.path.split("/") if s]
        rec = {"trace_id": span.trace_id, "token": req.token_label,
               "model": seg[1] if len(seg) >= 2 and seg[0] == "v1"
               else "", "op": seg[2] if len(seg) >= 3 else req.path,
               "status": status,
               "code": err.get("code") if isinstance(err, dict)
               else None,
               "latency_ms": round(span.duration_ms, 3)}
        if isinstance(body, dict):
            for k in ("bucket", "batch_size", "queue_wait_ms",
                      "dispatch_ms"):
                if k in body:
                    rec[k] = body[k]
        self.tel.log.request(**rec)

    async def _route(self, req: HTTPRequest) \
            -> Union[dict, RawResult]:
        path, method = req.path, req.method
        if path == "/healthz":
            self._need(method, "GET")
            try:
                out = await self.gateway.healthz(trace=req.trace)
            except PortalError as e:
                if e.code != "E_BRIDGE_DOWN":
                    raise
                # the bridge is redialing: this worker is up but can't
                # reach the dispatcher — report down with the reason
                # rather than a bare transport error
                out = {"ok": False, "status": "down",
                       "reason": str(e)}
            # which front-end process answered (the dispatcher's own
            # pid rides in `pid`) — Portal._wait_ready polls this to
            # confirm every SO_REUSEPORT worker is accepting
            out["worker_pid"] = os.getpid()
            if hasattr(self.gateway, "drops"):
                out["bridge"] = {"drops": self.gateway.drops,
                                 "reconnects": self.gateway.reconnects}
            status = out.get("status") or (
                "down" if out.get("ok") is False else "ok")
            if status == "down":
                # only DOWN answers 503 (load balancers drain this
                # backend while operators still see why); "degraded"
                # — supervisor mid-restart, stall suspicion — stays
                # 200 so one recoverable hiccup never ejects the node
                return RawResult(503, "application/json",
                                 json.dumps(out).encode("utf-8"))
            return out
        if path == "/metrics":
            self._need(method, "GET")
            if req.query.get("format") == "json":
                # legacy JSON shape; `clients` stays worker-local by
                # design (it reports the answering worker's quota
                # table — the aggregated view is the Prometheus text)
                stats = await self.gateway.stats(trace=req.trace)
                return {"server": stats,
                        "clients": self.auth.metrics()}
            out = await self.gateway.metrics("prometheus",
                                             trace=req.trace)
            return RawResult(200, out.get(
                "content_type",
                "text/plain; version=0.0.4; charset=utf-8"),
                out["text"].encode("utf-8"))
        if path == "/trace":
            self._need(method, "GET")
            return await self.gateway.trace_export(
                req.query.get("trace_id") or None, trace=req.trace)
        seg = [s for s in path.split("/") if s]
        if len(seg) >= 3 and seg[0] == "v1":
            return await self._v1(req, seg[1], seg[2:])
        raise PortalError(404, "E_NO_ROUTE",
                          f"no route for {method} {path}")

    async def _v1(self, req: HTTPRequest, model: str, rest) -> dict:
        # chaos site: a front-end worker dying mid-request (os._exit)
        # — fires only on model routes so health polls never trip it
        faults.fire("worker_exit")
        state = self.auth.authenticate(req.headers)
        if state is not None:
            req.token_label = state.name
        method = req.method
        trace = req.trace
        if rest == ["run"]:
            self._need(method, "POST")
            with self.auth.admit(state):
                return await self.gateway.run(model, req.json(),
                                              trace=trace)
        if rest == ["reconfigure"]:
            self._need(method, "POST")
            with self.auth.admit(state):
                return await self.gateway.reconfigure(model,
                                                      req.json(),
                                                      trace=trace)
        if rest == ["session"]:
            self._need(method, "POST")
            return await self.gateway.open_session(model, trace=trace)
        if len(rest) >= 2 and rest[0] == "session":
            sid = self._int(rest[1])
            if len(rest) == 2 and method == "GET":
                return await self.gateway.session_info(model, sid,
                                                       trace=trace)
            if len(rest) == 2 and method == "DELETE":
                return await self.gateway.close_session(model, sid,
                                                        trace=trace)
            if rest[2:] == ["reset"]:
                self._need(method, "POST")
                return await self.gateway.reset_session(model, sid,
                                                        trace=trace)
        raise PortalError(404, "E_NO_ROUTE",
                          f"no route for {method} {req.path}")

    async def _websocket(self, req: HTTPRequest,
                         reader: asyncio.StreamReader,
                         writer: asyncio.StreamWriter) -> None:
        """GET /v1/{model}/stream — auth happens BEFORE the 101, so a
        bad token is an ordinary HTTP 401, not a broken socket."""
        seg = [s for s in req.path.split("/") if s]
        try:
            if len(seg) != 3 or seg[0] != "v1" or seg[2] != "stream":
                raise PortalError(404, "E_NO_ROUTE",
                                  f"no websocket route for {req.path}")
            state = self.auth.authenticate(req.headers)
        except PortalError as e:
            writer.write(http_response(e.status, e.to_body(),
                                       headers=e.headers(),
                                       keep_alive=False))
            await writer.drain()
            return
        await _ws.handle_stream(self, req, reader, writer, seg[1],
                                state)

    # ------------------------------------------------------- helpers
    @staticmethod
    def _need(method: str, expected: str) -> None:
        if method != expected:
            raise PortalError(405, "E_METHOD",
                              f"use {expected} for this route, not "
                              f"{method}")

    @staticmethod
    def _int(s: str) -> int:
        try:
            return int(s)
        except ValueError:
            raise PortalError(400, "E_BAD_REQUEST",
                              f"session id must be an integer, got "
                              f"{s!r}")
