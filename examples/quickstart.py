"""Quickstart — the Appendix A.1 example network, verbatim API.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.api import ANN_neuron, CRI_network, LIF_neuron

# neuron models (A.1): a,b = LIF θ=3 almost-no-leak; c = LIF θ=4 λ=2;
# d = stochastic ANN θ=5
lif_ab = LIF_neuron(threshold=3, nu=-32, lam=60)
lif_c = LIF_neuron(threshold=4, nu=-32, lam=2)
ann_d = ANN_neuron(threshold=5, nu=0)

axons = {
    "alpha": [("a", 3), ("c", 2)],
    "beta": [("b", 3)],
}
neurons = {
    "a": ([("b", 1), ("a", 2)], lif_ab),
    "b": ([], lif_ab),
    "c": ([], lif_c),
    "d": ([("c", 1)], ann_d),
}
outputs = ["a", "b"]

network = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine")

print("== stepping the A.1 network ==")
for t in range(6):
    inputs = ["alpha", "beta"] if t % 2 == 0 else ["alpha"]
    fired = network.step(inputs)
    print(f"t={t} inputs={inputs} fired={fired}")

# monitor membrane potentials
fired, potentials = network.step(["beta"], membranePotential=True)
print("potentials:", potentials)

# A.1: increment the a->b synapse over the PCIe path
w = network.read_synapse("a", "b")
network.write_synapse("a", "b", w + 1)
print(f"synapse a->b: {w} -> {network.read_synapse('a', 'b')}")

# the hardware cost model (Table 2 instrumentation)
print("HBM access counter:", network.counter.as_dict())
