"""Quickstart — the Appendix A.1 example network, verbatim API, plus
the staged build→compile→deploy pipeline the dict facade sits on.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.api import ANN_neuron, CRI_network, LIF_neuron
from repro.core.compile import compile_spec
from repro.core.deploy import deploy
from repro.core.spec import NetworkSpec

# neuron models (A.1): a,b = LIF θ=3 almost-no-leak; c = LIF θ=4 λ=2;
# d = stochastic ANN θ=5
lif_ab = LIF_neuron(threshold=3, nu=-32, lam=60)
lif_c = LIF_neuron(threshold=4, nu=-32, lam=2)
ann_d = ANN_neuron(threshold=5, nu=0)

axons = {
    "alpha": [("a", 3), ("c", 2)],
    "beta": [("b", 3)],
}
neurons = {
    "a": ([("b", 1), ("a", 2)], lif_ab),
    "b": ([], lif_ab),
    "c": ([], lif_c),
    "d": ([("c", 1)], ann_d),
}
outputs = ["a", "b"]

network = CRI_network(axons=axons, neurons=neurons, outputs=outputs,
                      backend="engine")

print("== stepping the A.1 network ==")
for t in range(6):
    inputs = ["alpha", "beta"] if t % 2 == 0 else ["alpha"]
    fired = network.step(inputs)
    print(f"t={t} inputs={inputs} fired={fired}")

# monitor membrane potentials
fired, potentials = network.step(["beta"], membranePotential=True)
print("potentials:", potentials)

# A.1: increment the a->b synapse over the PCIe path
w = network.read_synapse("a", "b")
network.write_synapse("a", "b", w + 1)
print(f"synapse a->b: {w} -> {network.read_synapse('a', 'b')}")

# the hardware cost model (Table 2 instrumentation)
print("HBM access counter:", network.counter.as_dict())

# == the same network through the staged columnar API ==
# stage 1: columnar spec (bulk array construction — scales to millions
# of synapses with no per-synapse Python)
spec = NetworkSpec()
ax = spec.add_axons(2, keys=["alpha", "beta"])
nr_ab = spec.add_neurons(2, lif_ab, keys=["a", "b"])
nr_c = spec.add_neurons(1, lif_c, keys=["c"])
nr_d = spec.add_neurons(1, ann_d, keys=["d"])
a, b, c, d = int(nr_ab[0]), int(nr_ab[1]), int(nr_c[0]), int(nr_d[0])
spec.connect(np.array([ax[0], ax[0], ax[1], a, a, d]),
             np.array([a, c, b, b, a, c]),
             np.array([3, 2, 3, 1, 2, 1]))
spec.set_outputs([a, b])

# stage 2: compile to the packed HBM image (bit-identical to the dict
# route) — the artifact saves/loads for reuse
compiled = compile_spec(spec, target="engine")
print("staged image stats:", compiled.stats())

# stage 3: deploy and run; batched reconfiguration is one upload
dep = deploy(compiled, seed=0)
dep.run(np.ones((4, 2), np.int32))
dep.write_synapses([int(ax[0]), a], [a, b], [5, 2])   # ONE upload
print("staged read_synapses:",
      dep.read_synapses([int(ax[0]), a], [a, b]).tolist())
