"""Serving demo: DVS-style event streams through the always-on spike
server.

Eight concurrent clients each stream gesture-like ON/OFF event frames
(`repro.data.synthetic.event_frames` — the offline stand-in for
DVS-Gesture) at one resident recurrent SNN. Every client holds a
SESSION: its membrane state and noise stream persist across windows,
so the recurrent network integrates each client's gesture over time
exactly as if it were the only client — while the server micro-batches
all eight streams into single dispatches.

    PYTHONPATH=src python examples/serve_snn.py [--clients 8]

With `--portal`, the same eight streams run over localhost instead of
in-process: each client opens a websocket streaming session
(`GET /v1/dvs/stream`, lane-pinned, pipelined windows) against the
web-portal front end, and the recurrent state lives server-side
exactly as before. Add `--portal-workers 4` to fan the front end out
across bridged worker processes.

    PYTHONPATH=src python examples/serve_snn.py --portal
"""
import argparse
import threading
import time

import numpy as np

from repro.core.api import LIF_neuron
from repro.core.compile import compile_spec
from repro.core.spec import NetworkSpec
from repro.data.synthetic import event_frames
from repro.serve import SpikeServer


def dvs_network(n_axons, n_neurons=128, seed=0):
    """Random recurrent LIF network with ON-excitatory / OFF-inhibitory
    input projections — one axon per DVS pixel-channel."""
    rng = np.random.default_rng(seed)
    spec = NetworkSpec()
    ax = spec.add_axons(n_axons)
    nid = spec.add_neurons(n_neurons,
                           LIF_neuron(threshold=8, nu=-32, lam=30))
    on, off = ax[:n_axons // 2], ax[n_axons // 2:]
    fan = 4
    pre = np.concatenate([np.repeat(on, fan), np.repeat(off, fan),
                          np.repeat(nid, 3)])
    w = np.concatenate([rng.integers(2, 7, on.size * fan),
                        rng.integers(-6, -1, off.size * fan),
                        rng.integers(-2, 5, nid.size * 3)])
    post = rng.integers(0, n_neurons, pre.shape[0])
    spec.connect(pre, post, w)
    spec.set_outputs(list(range(min(16, n_neurons))))
    return spec


def frames_to_windows(sample):
    """(frames, 2, H, W) bool events -> (frames, 2*H*W) int32 counts:
    one serving window per gesture, one timestep per DVS frame."""
    return sample.reshape(sample.shape[0], -1).astype(np.int32)


def stream_client(srv, cid, samples, results):
    sid = srv.open_session("dvs")
    rates = []
    for s in samples:
        res = srv.submit("dvs", frames_to_windows(s),
                         session=sid).result(timeout=300)
        rates.append(float(res.spikes.mean()))
    results[cid] = {"session": sid, "rates": rates,
                    "final_V": srv.session_membrane("dvs", sid)}
    srv.close_session("dvs", sid)


def stream_client_ws(port, cid, samples, results):
    """Same gesture stream, but over the web portal: one websocket
    session per client, every window pipelined onto the wire before
    the first result is read."""
    from repro.portal import WSClient

    ws = WSClient("127.0.0.1", port, "dvs")
    for s in samples:                    # pipeline: send all, then read
        ws.send_window(counts=frames_to_windows(s))
    rates, final_V = [], None
    for _ in samples:
        msg = ws.recv()
        rates.append(float(np.asarray(msg["spikes"]).mean()))
        final_V = np.asarray(msg["membrane"])
    ws.close()                           # lane released server-side
    results[cid] = {"session": ws.session, "rates": rates,
                    "final_V": final_V}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--portal", action="store_true",
                    help="stream over localhost websockets through the "
                         "web-portal front end instead of in-process")
    ap.add_argument("--portal-workers", type=int, default=0,
                    help="with --portal: bridged front-end worker "
                         "processes (0 = in-process front end)")
    ap.add_argument("--samples", type=int, default=3,
                    help="gestures streamed per client")
    ap.add_argument("--shape", type=int, default=12,
                    help="DVS sensor side length (pixels)")
    ap.add_argument("--frames", type=int, default=8,
                    help="event frames per gesture = serving window")
    ap.add_argument("--neurons", type=int, default=128)
    args = ap.parse_args()
    H = W = args.shape
    n_axons = 2 * H * W

    print(f"== 1. synthetic DVS gestures ({H}x{W}, 2 channels, "
          f"{args.frames} frames) ==")
    X, y = event_frames(args.clients * args.samples, shape=(H, W),
                        frames=args.frames, seed=0)
    per_client = X.reshape(args.clients, args.samples, *X.shape[1:])

    print(f"== 2. resident recurrent SNN ({n_axons} axons, "
          f"{args.neurons} neurons) on the event engine ==")
    compiled = compile_spec(dvs_network(n_axons, args.neurons),
                            target="engine")
    srv = SpikeServer(max_batch=args.clients, max_wait_ms=4.0)
    srv.add_model("dvs", compiled, window=args.frames,
                  n_sessions=args.clients, seed=0)

    how = ("websocket streams through the web portal" if args.portal
           else "in-process sessions")
    print(f"== 3. {args.clients} clients streaming "
          f"{args.samples} gestures each ({how}) ==")
    results = {}
    with srv:
        # warm the compile caches (lone request + full-width burst) so
        # latencies below are serving times, not tracing times
        srv.submit("dvs", np.zeros((args.frames, n_axons),
                                   np.int32)).result()
        for f in [srv.submit("dvs", np.zeros((args.frames, n_axons),
                                             np.int32))
                  for _ in range(args.clients)]:
            f.result()
        srv.reset_stats()

        def run_clients(target, *extra):
            t0 = time.monotonic()
            ts = [threading.Thread(target=target,
                                   args=(*extra, c, per_client[c],
                                         results))
                  for c in range(args.clients)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            return time.monotonic() - t0

        if args.portal:
            from repro.portal import Portal

            with Portal(srv, port=0,
                        workers=args.portal_workers) as portal:
                print(f"   portal at {portal.url} "
                      f"({args.portal_workers or 'no'} bridged "
                      f"workers)")
                wall = run_clients(stream_client_ws, portal.port)
        else:
            wall = run_clients(stream_client, srv)
        stats = srv.stats()

    total = args.clients * args.samples
    print(f"   {total} gesture windows in {wall:.3f}s "
          f"({total / wall:.1f} windows/s)")
    print(f"   p50 {stats['p50_ms']:.2f} ms, p99 {stats['p99_ms']:.2f} "
          f"ms, mean micro-batch {stats['mean_batch_size']:.2f}, "
          f"buffer swaps {stats['buffer']['swaps']}")
    print(f"   compiled batch shapes: "
          f"{stats['models']['dvs']['batch_shapes']}")
    for c in sorted(results):
        r = results[c]
        print(f"   client {c} (lane {r['session']}): spike rates "
              f"{['%.3f' % v for v in r['rates']]}, "
              f"|V| max {int(np.abs(r['final_V']).max())}")
    # sessions persisted: a streaming client's state must be non-trivial
    assert all(len(r["rates"]) == args.samples for r in results.values())


if __name__ == "__main__":
    main()
