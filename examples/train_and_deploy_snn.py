"""End-to-end driver (§6 pipeline): QAT-train an MLP classifier in JAX,
quantize to int16, convert to a HiAER-Spike network (A.2), run inference on
the event-driven HBM engine, and report accuracy + energy/latency — the
Table 2 protocol on the synthetic stand-in dataset (DESIGN.md §7).

    PYTHONPATH=src python examples/train_and_deploy_snn.py [--epochs 6]
"""
import argparse

import numpy as np

from repro.core.convert import (LayerSpec, QATModel, apply_quantized,
                                infer_image, quantize, to_network, train_qat)
from repro.data.synthetic import digits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--n-train", type=int, default=1500)
    ap.add_argument("--n-test", type=int, default=100)
    ap.add_argument("--hidden", type=int, default=64)
    args = ap.parse_args()

    print("== 1. synthetic binarized digits (28x28, MNIST-shaped) ==")
    X, y = digits(args.n_train + args.n_test, shape=(28, 28), seed=0)
    Xf = X.reshape(-1, 1, 28, 28).astype(np.float32)
    Xtr, ytr = Xf[:args.n_train], y[:args.n_train]
    Xte, yte = X[args.n_train:], y[args.n_train:]

    print("== 2. QAT training (binary activations, STE) ==")
    model = QATModel(input_shape=(1, 28, 28),
                     layers=[LayerSpec("dense", out_features=args.hidden)],
                     n_classes=10)
    params = train_qat(model, Xtr, ytr, epochs=args.epochs, verbose=True)

    print("== 3. int16 quantization ==")
    qp, bits = quantize(params)
    ref = apply_quantized(model, qp,
                          Xf[args.n_train:].astype(np.int64))
    sw_acc = float((ref.argmax(1) == yte).mean())
    print(f"   scale 2^{bits}; software (quantized) acc = {sw_acc:.4f}")

    print("== 4. convert to HiAER-Spike (A.2) & deploy on the engine ==")
    net, out_keys = to_network(model, qp, backend="engine")
    stats = net.image.stats()
    print(f"   HBM: {stats['hbm_rows']} rows, packing density "
          f"{stats['packing_density']:.3f}")

    correct = 0
    net.counter.reset()
    mismatch = 0
    for i in range(args.n_test):
        pred, pots = infer_image(net, Xte[i], model, out_keys)
        correct += pred == yte[i]
        mismatch += not np.array_equal(np.asarray(pots), ref[i])
    hw_acc = correct / args.n_test
    c = net.counter.as_dict()
    print(f"   HiAER acc = {hw_acc:.4f} (software {sw_acc:.4f}, "
          f"potential mismatches: {mismatch})")
    print(f"   per-inference: energy = "
          f"{c['energy_uJ'] / args.n_test:.2f} uJ, latency = "
          f"{c['latency_us'] / args.n_test:.2f} us "
          f"({c['total_accesses'] / args.n_test:.0f} HBM accesses)")
    assert mismatch == 0, "conversion must be bit-exact"


if __name__ == "__main__":
    main()
