"""Serve a small LM with batched requests (continuous-batching loop).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2_5_3b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "qwen2_5_3b"]
    if "--reduced" not in argv:
        argv.append("--reduced")
    main(argv)
