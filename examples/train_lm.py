"""Train an LM with the production launcher (checkpointed, resumable,
watchdogged) — reduced config on CPU; pass a full arch + --mesh single on a
real pod.

    PYTHONPATH=src python examples/train_lm.py --arch mamba2_780m --steps 50
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "mamba2_780m", "--steps", "50"]
    if "--reduced" not in argv:
        argv.append("--reduced")
    main(argv)
