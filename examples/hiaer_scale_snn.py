"""Distributed SNN with hierarchical HiAER routing — small live run on the
local mesh + instructions for the 160M-neuron/40B-synapse dry-run.

    PYTHONPATH=src python examples/hiaer_scale_snn.py
    # full-scale (dry-run, 512 virtual chips):
    PYTHONPATH=src python -m repro.launch.dryrun --arch hiaer_snn_40b \
        --shape step_160M_40B --mesh both
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed_engine import (SNNShardConfig, make_snn_step,
                                           small_reference_step)
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_local_mesh

cfg = SNNShardConfig(n_neurons=4096, avg_fan_in=128, fan_window_blocks=2)
mesh = make_local_mesh()
key = jax.random.PRNGKey(0)
W = cfg.fan_window_blocks * cfg.block

state = {
    "V": jax.random.randint(key, (cfg.n_neurons,), 0, 200, jnp.int32),
    "theta": jax.random.randint(jax.random.fold_in(key, 9),
                                (cfg.n_neurons,), 200, 2500, jnp.int32),
    "lam": jnp.full((cfg.n_neurons,), 4, jnp.int32),
    "weights": jax.random.randint(key, (W, cfg.n_neurons), -35, 60,
                                  jnp.int16),
    "spikes": jax.random.bernoulli(key, 0.05, (cfg.n_neurons,)),
}

with mesh_context(mesh):
    step = jax.jit(make_snn_step(cfg, mesh))
    s = state
    for t in range(10):
        s = step(s, jax.random.fold_in(key, t))
        rate = float(jnp.mean(s["spikes"]))
        print(f"t={t}: spike rate {rate:.4f}, "
              f"mean |V| {float(jnp.mean(jnp.abs(s['V']))):.1f}")

print("OK — scale this to 160M neurons / 40B synapses with the dry-run "
      "command in the module docstring (the paper's full-platform target).")
